"""Deployment backend API tests: registry resolution, capabilities,
the a2a deployment, stable cross-process seeding, the content-addressed
run cache, the RunEvent wire protocol, and RunMonitor parity across
FaaS / A2A transport boundaries."""
import dataclasses
import inspect
import json
import os
import subprocess
import sys

import pytest

from repro.apps.cache import RunCache, spec_fingerprint
from repro.apps.session import RunSpec, Session, stable_world_seed
from repro.core.events import (RunCompleted, RunStarted, events_from_wire,
                               events_to_wire, derive_trace, from_wire,
                               to_wire)
from repro.core.runtime import register_pattern, resolve_pattern
from repro.core import runtime as rt
from repro.env.world import World
from repro.faas.deployments import (DeploymentBackend, RunServiceClient,
                                    create_deployment, deploy_monolithic,
                                    deployment_names, register_deployment,
                                    resolve_deployment)
from repro.faas import deployments as dep_mod
from repro.faas.platform import FaaSPlatform
from repro.mcp.a2a import A2AClient, expose_app_as_agent
from repro.serving.engine import RunMonitor

SPEC = RunSpec("web_search", "quantum", "react", "local", seed=0)


# -- registry ---------------------------------------------------------------


def test_all_four_deployments_registered():
    assert deployment_names() == ["local", "faas", "faas-mono", "a2a"]
    for name in deployment_names():
        rd = resolve_deployment(name)
        assert rd.name == name
        assert issubclass(rd.backend_cls, DeploymentBackend)
        assert rd.capabilities.name == name
        backend = create_deployment(name)
        assert isinstance(backend, rd.backend_cls)
        assert backend.capabilities is rd.capabilities


def test_unknown_deployment_lists_registered():
    with pytest.raises(KeyError, match="faas-mono"):
        resolve_deployment("nope")


def test_capability_descriptors():
    assert not resolve_deployment("local").capabilities.remote
    assert resolve_deployment("local").capabilities.description_hints
    faas = resolve_deployment("faas").capabilities
    assert faas.remote and faas.tool_subset and faas.cost_accounting
    assert faas.artifact_store == "s3"
    a2a = resolve_deployment("a2a").capabilities
    assert a2a.remote and not a2a.cost_accounting
    assert "paper" in resolve_deployment("faas").capabilities.tags
    assert deployment_names(tag="paper") == ["local", "faas"]


def test_session_execute_has_no_deployment_name_branches():
    """The acceptance criterion, literally: Session's run path contains no
    deployment-name string comparisons — everything resolves through the
    registry."""
    src = inspect.getsource(Session._execute) + inspect.getsource(
        Session.execute)
    for name in ("local", "faas", "faas-mono", "a2a"):
        assert f'"{name}"' not in src and f"'{name}'" not in src


def test_register_deployment_decorator_variant():
    @register_deployment("test-local-clone", rank=99)
    class _Clone(resolve_deployment("local").backend_cls):
        pass

    try:
        r = Session().execute(dataclasses.replace(
            SPEC, deployment="test-local-clone"))
        assert r.success
        assert r.deployment == "test-local-clone"
    finally:
        dep_mod._DEPLOYMENTS.pop("test-local-clone", None)


# -- the a2a deployment -----------------------------------------------------


def test_a2a_deployment_end_to_end():
    r = Session().execute(dataclasses.replace(SPEC, deployment="a2a"))
    assert r.success
    assert r.artifact_path.startswith("s3://")   # shared object store
    assert r.faas_cost == 0.0                    # no Lambda platform
    assert isinstance(r.extras["events"][-1], RunCompleted)
    # every MCP call paid the A2A task round trip
    assert r.trace.tool_latency > 0


def test_a2a_metrics_deterministic():
    spec = dataclasses.replace(SPEC, deployment="a2a")
    r1, r2 = Session().execute(spec), Session().execute(spec)
    assert r1.total_latency == r2.total_latency
    assert r1.trace.input_tokens == r2.trace.input_tokens


# -- stable seeding ---------------------------------------------------------


def test_world_seed_is_hashseed_independent():
    """builtin hash() is randomized per process; the world seed must not
    be. Run the derivation under two different PYTHONHASHSEEDs."""
    code = ("import sys; sys.path.insert(0, 'src');"
            "from repro.apps.session import RunSpec, stable_world_seed;"
            "print(stable_world_seed("
            "RunSpec('web_search', 'quantum', 'react', 'faas', seed=3)))")
    seeds = []
    for hashseed in ("1", "2"):
        env = dict(os.environ, PYTHONHASHSEED=hashseed,
                   JAX_PLATFORMS="cpu")
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True,
                             cwd=os.path.join(os.path.dirname(__file__),
                                              ".."))
        assert out.returncode == 0, out.stderr
        seeds.append(int(out.stdout.strip()))
    assert seeds[0] == seeds[1]
    assert seeds[0] == stable_world_seed(
        RunSpec("web_search", "quantum", "react", "faas", seed=3))


# -- run cache --------------------------------------------------------------


def test_run_cache_hit_returns_stored_result():
    cache = RunCache()
    session = Session(cache=cache)
    r1 = session.execute(SPEC)
    r2 = session.execute(SPEC)
    assert r1 is r2
    assert cache.stats() == {"entries": 1, "hits": 1, "misses": 1}


def test_run_cache_keys_distinguish_specs():
    keys = {spec_fingerprint(RunSpec("web_search", "quantum", p, d, seed=s))
            for p in ("react", "agentx") for d in ("local", "faas")
            for s in (0, 1)}
    assert len(keys) == 8


def test_run_cache_invalidates_on_pattern_config_change():
    base = resolve_pattern("react")

    @register_pattern("test-cached", max_steps=25)
    class _V1(base.runner_cls):
        pass

    try:
        spec = dataclasses.replace(SPEC, pattern="test-cached")
        key1 = spec_fingerprint(spec)
        # re-register under the same name with a different knob
        register_pattern("test-cached", max_steps=3)(_V1)
        key2 = spec_fingerprint(spec)
        assert key1 != key2
    finally:
        rt._REGISTRY.pop("test-cached", None)


def test_run_cache_invalidates_on_deployment_capability_change():
    local_cls = resolve_deployment("local").backend_cls
    register_deployment("test-dep")(local_cls)
    try:
        spec = dataclasses.replace(SPEC, deployment="test-dep")
        key1 = spec_fingerprint(spec)
        register_deployment("test-dep", rank=77)(local_cls)
        assert spec_fingerprint(spec) != key1
    finally:
        dep_mod._DEPLOYMENTS.pop("test-dep", None)


def test_custom_backend_factory_is_not_cacheable():
    spec = dataclasses.replace(SPEC, backend_factory=lambda *a: None)
    assert spec_fingerprint(spec) is None
    cache = RunCache()
    assert cache.get(None) is None
    assert cache.stats()["misses"] == 0     # None keys don't count


def test_execute_many_shares_cache_across_workers():
    cache = RunCache()
    session = Session(cache=cache)
    specs = [SPEC] * 6
    results = session.execute_many(specs, max_workers=3)
    assert len(results) == 6
    stats = cache.stats()
    assert stats["entries"] == 1
    assert stats["hits"] + stats["misses"] == 6
    fps = {(r.total_latency, r.trace.input_tokens) for r in results}
    assert len(fps) == 1


def test_warm_cache_makes_run_sweep_free(tmp_path, monkeypatch):
    """Acceptance: a repeated run_sweep on a warm session re-executes
    nothing (misses stay flat, hits grow)."""
    from benchmarks import experiments

    monkeypatch.setattr(experiments, "CACHE",
                        str(tmp_path / "agent_runs.json"))
    monkeypatch.setattr(experiments, "N_SUCCESS", 1)
    monkeypatch.setattr(experiments, "MAX_RUNS", 2)
    cache = RunCache()
    session = Session(cache=cache)
    first = experiments.run_sweep(full=False, deployments=["local"],
                                  force=True, session=session)
    misses_after_cold = cache.stats()["misses"]
    assert misses_after_cold > 0
    second = experiments.run_sweep(full=False, deployments=["local"],
                                   force=True, session=session)
    assert cache.stats()["misses"] == misses_after_cold   # zero re-runs
    assert cache.stats()["hits"] >= misses_after_cold
    assert json.dumps(first) == json.dumps(second)


# -- event wire protocol ----------------------------------------------------


def test_event_wire_round_trip_identity():
    r = Session().execute(dataclasses.replace(SPEC, pattern="agentx"))
    events = r.extras["events"]
    wire = events_to_wire(events)
    json.dumps(wire)                       # JSON-safe by construction
    back = events_from_wire(wire)
    assert back == events
    derived = derive_trace(back)
    assert derived.llm_events == r.trace.llm_events
    assert derived.tool_events == r.trace.tool_events
    assert derived.framework_events == r.trace.framework_events


def test_event_wire_unknown_type():
    ev = RunStarted(t=0.0, pattern="react", task="x")
    d = to_wire(ev)
    assert d["type"] == "RunStarted"
    assert from_wire(d) == ev
    with pytest.raises(KeyError, match="unknown RunEvent"):
        from_wire({"type": "NotAnEvent"})


# -- cross-boundary event streaming -----------------------------------------


def _reference_run(monitor):
    return Session(on_event=monitor).execute(SPEC)


def test_run_monitor_parity_across_faas_boundary():
    mon_local, mon_remote = RunMonitor(), RunMonitor()
    r = _reference_run(mon_local)
    seen = []

    def observe(ev):
        seen.append(ev)
        mon_remote(ev)

    platform = FaaSPlatform(World(0))
    svc = RunServiceClient(platform, on_event=observe)
    remote = svc.execute("web_search", "quantum", "react", "local", 0)
    assert remote["success"] == r.success
    assert remote["total_latency"] == r.total_latency
    assert seen == r.extras["events"]
    assert mon_remote.snapshot() == mon_local.snapshot()
    # the remote run's virtual time is billed on the service function
    assert platform.total_cost() > 0


def test_run_monitor_parity_across_a2a_boundary():
    mon_local, mon_remote = RunMonitor(), RunMonitor()
    _reference_run(mon_local)
    world = World(9)
    client = A2AClient(world, on_event=mon_remote)
    agent = expose_app_as_agent(world, "web_search", "react", "local",
                                "https://x/ws")
    client.discover(agent)
    task = client.delegate(agent.card.name, "web_search", "quantum")
    assert task.status == "completed"
    assert mon_remote.snapshot() == mon_local.snapshot()


def test_run_service_rejects_unknown_method():
    platform = FaaSPlatform(World(0))
    svc = RunServiceClient(platform)
    from repro.mcp.protocol import McpRequest
    resp = svc.transport.send(McpRequest("tools/call", {"name": "x"}, id=7))
    assert not resp.ok
    assert "unknown method" in resp.error["message"]


def test_run_service_rejects_invalid_spec():
    """Bad run params come back as a JSON-RPC error envelope, not a raw
    exception escaping the simulated Lambda."""
    svc = RunServiceClient(FaaSPlatform(World(0)))
    with pytest.raises(RuntimeError, match="invalid run spec"):
        svc.execute("no-such-app", "x", "react")
    with pytest.raises(RuntimeError, match="invalid run spec"):
        svc.execute("web_search", "quantum", "no-such-pattern")


# -- platform routing -------------------------------------------------------


def test_invoke_url_unknown_url_is_jsonrpc_error():
    platform = FaaSPlatform(World(0))
    raw = platform.invoke_url("https://nowhere.lambda-url.x.on.aws/",
                              json.dumps({"jsonrpc": "2.0", "id": 5,
                                          "method": "tools/list",
                                          "params": {}}))
    body = json.loads(raw)
    assert body["id"] == 5
    assert body["error"]["code"] == -32601
    assert "no function at" in body["error"]["message"]


def test_invoke_url_is_indexed_after_redeploy():
    world = World(0)
    platform = FaaSPlatform(world)
    fn1 = platform.deploy("mcp-x", dep_mod.SERVER_FACTORIES["serper"],
                          memory_mb=128)
    fn2 = platform.deploy("mcp-x", dep_mod.SERVER_FACTORIES["serper"],
                          memory_mb=256)
    assert fn1.url == fn2.url                     # AWS redeploy semantics
    assert platform._by_url[fn1.url] is fn2


def test_monolithic_unknown_server_param_is_tool_error():
    from repro.mcp.client import FaaSTransport, McpClient
    world = World(0)
    platform = FaaSPlatform(world)
    deploy_monolithic(world, platform, ["serper"])
    fn = platform.functions["mcp-monolith"]
    client = McpClient(FaaSTransport(platform, fn.url,
                                     server_name="nosuch"), "nosuch")
    with pytest.raises(RuntimeError, match="unknown server 'nosuch'"):
        client.initialize()
    out_client = McpClient(FaaSTransport(platform, fn.url,
                                         server_name="serper"), "serper")
    out_client.initialize()
    # a bad server param on tools/call surfaces as a tool error string
    bad = McpClient(FaaSTransport(platform, fn.url, server_name="wrong"),
                    "wrong")
    bad.session_id = out_client.session_id
    out = bad.call_tool("google_search", {"query": "x"})
    assert out.startswith("<tool-error") and "unknown server" in out
