import os

# Smoke tests and benches must see ONE device (the dry-run re-execs with
# 512 host devices itself; never set that globally).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng_key():
    import jax
    return jax.random.key(0)
