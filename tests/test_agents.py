"""Agent-pattern behaviour tests: AgentX structure, ReAct loop semantics,
Magentic-One orchestration, and the paper's qualitative claims."""
import statistics

import pytest

from repro.apps.runner import PATTERNS, run_app, run_until_n_successes, score_run

SEEDS = range(4)


def _avg(vals):
    return statistics.mean(vals)


def test_agentx_three_agent_roles():
    r = run_app("web_search", "quantum", "agentx", "local", seed=3)
    roles = r.trace.agent_breakdown()
    assert "stage_generator" in roles and roles["stage_generator"] == 1
    assert roles["planner"] >= 2          # one per stage
    assert roles["executor"] >= roles["planner"]   # exec loop >= stages


def test_agentx_summaries_cross_stages():
    r = run_app("web_search", "quantum", "agentx", "local", seed=3)
    summaries = r.extras["outcome"]["summaries"]
    assert len(summaries) >= 2
    # context consolidation: summaries are much smaller than raw fetches
    assert all(len(s) < 4000 for s in summaries)


def test_react_single_agent_and_refetch():
    r = run_app("web_search", "quantum", "react", "local", seed=0)
    assert set(r.trace.agent_breakdown()) == {"react"}
    tools = r.trace.tool_breakdown()
    # truncation-driven re-fetch: ~2 fetch calls per URL (paper §6.2)
    assert tools.get("fetch", 0) >= 8


def test_magentic_fact_sheet_plan_inferences():
    r = run_app("research_report", "why", "magentic", "local", seed=1)
    roles = r.trace.agent_breakdown()
    # fact sheet + plan + final = at least 3 orchestrator inferences
    assert roles.get("orchestrator", 0) >= 3


def test_input_tokens_ordering_web_search():
    """Paper §5.4.3: AgentX consumes far fewer input tokens than ReAct on
    web search (single growing context vs per-stage contexts)."""
    react = _avg([run_app("web_search", "edge", "react", "local", s).trace
                  .input_tokens for s in SEEDS])
    agentx = _avg([run_app("web_search", "edge", "agentx", "local", s).trace
                   .input_tokens for s in SEEDS])
    assert agentx < 0.6 * react


def test_latency_ordering_web_search():
    """Paper §5.4.2: ReAct faster than AgentX on web search (local)."""
    react = _avg([run_app("web_search", "edge", "react", "local", s)
                  .total_latency for s in SEEDS])
    agentx = _avg([run_app("web_search", "edge", "agentx", "local", s)
                   .total_latency for s in SEEDS])
    assert react < agentx


def test_react_success_rate_highest():
    """Paper: ReAct 100% success on local runs (recovery until final)."""
    for app, inst in [("web_search", "quantum"),
                      ("stock_correlation", "apple"),
                      ("research_report", "flow")]:
        runs = [run_app(app, inst, "react", "local", seed=s) for s in SEEDS]
        assert all(r.success for r in runs), (app, [r.failure_reason for r in runs])


def test_magentic_stock_truncation_hurts_accuracy():
    """Paper §5.4.1: Magentic-One truncates/fabricates stock data ->
    Data Accuracy/Query Adherence collapse vs ReAct."""
    react = _avg([score_run(run_app("stock_correlation", "apple", "react",
                                    "local", s)).total for s in SEEDS])
    mag = _avg([score_run(run_app("stock_correlation", "apple", "magentic",
                                  "local", s)).total for s in SEEDS])
    assert mag < react - 10


def test_success_rate_protocol():
    succ, runs = run_until_n_successes("web_search", "quantum", "react",
                                       "local", n=3, max_runs=10)
    assert len(succ) == 3
    rate = len(succ) / len(runs)
    assert rate == 1.0


def test_faas_writes_go_to_s3():
    r = run_app("research_report", "flow", "react", "faas", seed=0)
    assert r.success
    assert r.artifact_path.startswith("s3://")


def test_faas_monolithic_deployment_runs():
    r = run_app("web_search", "materials", "react", "faas-mono", seed=0)
    assert r.success
    assert r.faas_cost > 0


def test_lambda_cost_negligible_vs_llm():
    """Paper §5.4.5: FaaS cost ~2 orders below LLM inference cost."""
    r = run_app("web_search", "quantum", "agentx", "faas", seed=2)
    assert r.faas_cost < 0.05 * r.trace.llm_cost


def test_agentx_no_recovery_failure_mode():
    """Missing plan params -> dummy path -> failed run (§6.1), seeds where
    the anomaly triggers produce success=False, never a crash."""
    outcomes = [run_app("research_report", "why", "agentx", "local", seed=s)
                for s in range(12)]
    assert any(not r.success for r in outcomes)
    assert all(r.failure_reason == "" or "Error" not in r.failure_reason
               for r in outcomes)
