"""Serving API redesign: LLM-backend registry + continuous-batching
slot decode.

Covers the acceptance criteria of the redesign:
  * greedy batched decode is BIT-IDENTICAL to serial per-request
    generation (per arch family: GQA, MLA, SSM, sliding-window ring);
  * sampled decode too — sampling is keyed by (seed, rid, step), never
    by shared mutable RNG state, so interleaving cannot change results;
  * scheduler admission / slot-free / re-admission under mixed lengths;
  * EngineClient multiplexes concurrent callers onto one decode batch;
  * all LLM backends resolve via @register_llm_backend and
    Session.execute carries no backend-name branches;
  * RunCache persists wire-serialized results to disk.
"""
import dataclasses
import tempfile
from concurrent.futures import ThreadPoolExecutor

import pytest

from repro.apps.cache import RunCache, spec_fingerprint
from repro.apps.session import RunSpec, Session
from repro.configs import get_config
from repro.core.events import EngineStepped, from_wire, to_wire
from repro.serving import (BatchScheduler, Engine, EngineClient, RunMonitor,
                           get_llm_backend, llm_backend_names,
                           register_llm_backend, reset_llm_backends,
                           resolve_llm_backend, write_slot)
from repro.serving.api import JaxServing


PROMPTS = ["hello world", "a much longer prompt about agents and tools",
           "x", "another prompt", "fifth!", "sixth prompt here"]


def _parity_engine(arch, **over):
    cfg = get_config(arch).reduced()
    if over:
        cfg = dataclasses.replace(cfg, **over)
    return Engine(cfg, temperature=0.0)


# ---------------------------------------------------------------------------
# batched-vs-serial parity


@pytest.mark.parametrize("arch,over", [
    ("tinyllama-1.1b", {}),                      # GQA
    ("deepseek-v2-236b", {}),                    # MLA compressed cache
    ("mamba2-370m", {}),                         # SSM (position-free state)
    ("zamba2-7b", {}),                           # hybrid two-level stacks
    ("tinyllama-1.1b", {"sliding_window": 16}),  # ring-buffer cache
], ids=["gqa", "mla", "ssm", "hybrid", "window"])
def test_greedy_batched_matches_serial_bit_identical(arch, over):
    eng = _parity_engine(arch, **over)
    sched = BatchScheduler(eng, n_slots=3, max_len=64)
    maxn = [8, 5, 12, 7, 9, 6]
    rids = [sched.submit(p, max_new=m) for p, m in zip(PROMPTS, maxn)]
    results = sched.drain()
    assert set(results) == set(rids)
    for rid, m in zip(rids, maxn):
        req = sched.requests[rid]
        ref = eng.generate_ids(req.prompt_ids, m, rid=rid,
                               cache_len=sched.max_len)
        assert results[rid].token_ids == ref.token_ids, \
            f"rid {rid}: batched decode diverged from serial"


def test_sampled_batched_matches_serial():
    """Per-request RNG: (seed, rid, step)-keyed sampling makes batched
    and serial runs sample identically even at temperature > 0."""
    cfg = get_config("tinyllama-1.1b").reduced()
    eng = Engine(cfg, temperature=0.8, top_p=0.9, seed=3)
    sched = BatchScheduler(eng, n_slots=2, max_len=64)
    rids = [sched.submit(p, max_new=6) for p in PROMPTS[:4]]
    results = sched.drain()
    for rid in rids:
        req = sched.requests[rid]
        ref = eng.generate_ids(req.prompt_ids, 6, rid=rid,
                               cache_len=sched.max_len)
        assert results[rid].token_ids == ref.token_ids


def test_sampling_independent_of_interleaving():
    """The engine no longer mutates shared RNG state: a request's tokens
    do not depend on what was generated before it (thread-safety)."""
    cfg = get_config("tinyllama-1.1b").reduced()
    eng = Engine(cfg, temperature=1.0, seed=7)
    ids = eng.tokenizer.encode("interleaving probe")
    a = eng.generate_ids(ids, 6, rid=5)
    eng.generate_ids(eng.tokenizer.encode("other traffic"), 4, rid=1)
    eng.generate_ids(eng.tokenizer.encode("more traffic"), 3, rid=2)
    b = eng.generate_ids(ids, 6, rid=5)
    assert a.token_ids == b.token_ids


def test_write_slot_covers_hybrid_cache():
    """Slot insertion handles every cache family, including the hybrid
    two-level stacks (groups of SSM states + shared-attn KV)."""
    import jax
    import jax.numpy as jnp
    from repro.models.model import init_cache
    cfg = get_config("zamba2-7b").reduced()
    big = init_cache(cfg, 3, 32)
    # batch-1 row of the same tree shapes, filled with ones
    row = jax.tree_util.tree_map(lambda x: jnp.ones_like(x), _take_row(big, 0))
    out = write_slot(big, row, 1)
    flat_out = jax.tree_util.tree_leaves(_take_row(out, 1))
    assert all(bool(jnp.all(x == 1)) for x in flat_out)
    flat_other = jax.tree_util.tree_leaves(_take_row(out, 0))
    assert all(bool(jnp.all(x == 0)) for x in flat_other)


def _take_row(cache, slot):
    import jax
    from repro.serving.engine import cache_leaf_name
    from repro.serving.scheduler import _ROW_AXIS_OFFSET

    def take(path, x):
        axis = x.ndim - _ROW_AXIS_OFFSET[cache_leaf_name(path)]
        return jax.lax.slice_in_dim(x, slot, slot + 1, axis=axis)
    return jax.tree_util.tree_map_with_path(take, cache)


# ---------------------------------------------------------------------------
# scheduler mechanics


def test_scheduler_admission_slot_free_and_reuse():
    """Mixed lengths: finished sequences free their slot mid-flight and
    queued requests are admitted into it (continuous batching)."""
    eng = _parity_engine("tinyllama-1.1b")
    monitor = RunMonitor()
    sched = BatchScheduler(eng, n_slots=2, max_len=64, on_event=monitor)
    maxn = [2, 10, 3, 8, 2]
    rids = [sched.submit(f"prompt {i}", max_new=m)
            for i, m in enumerate(maxn)]
    seen_queued = False
    occupancies = []
    while sched.has_work():
        sched.step()
        occupancies.append(sched.occupancy())
        seen_queued = seen_queued or monitor.engine_queued > 0
    assert seen_queued, "5 requests on 2 slots must queue"
    assert max(occupancies + [0]) <= 2
    assert monitor.engine_peak_live == 2
    assert all(sched.requests[r].done for r in rids)
    assert all(s is None for s in sched.slots)
    for r, m in zip(rids, maxn):
        assert 1 <= len(sched.requests[r].out_ids) <= m


def test_scheduler_clamps_to_slot_context():
    eng = _parity_engine("tinyllama-1.1b")
    sched = BatchScheduler(eng, n_slots=1, max_len=32)
    rid = sched.submit("p" * 500, max_new=99)   # overlong prompt + budget
    req = sched.requests[rid]
    # max_new is clamped to the slot context minus one and always
    # honored; the prompt keeps whatever tail still fits (not the
    # historical max_len // 2 bite out of every long prompt)
    assert req.max_new == 31
    assert len(req.prompt_ids) >= 1
    assert len(req.prompt_ids) + req.max_new <= 32
    results = sched.drain()
    assert rid in results


def test_engine_client_multiplexes_threads():
    """Concurrent generate() callers share the decode batch and each gets
    exactly the tokens serial generation would produce."""
    eng = _parity_engine("tinyllama-1.1b")
    monitor = RunMonitor()
    sched = BatchScheduler(eng, n_slots=4, max_len=64, on_event=monitor)
    client = EngineClient(sched)
    with ThreadPoolExecutor(max_workers=6) as pool:
        outs = list(pool.map(lambda p: client.generate(p, 8), PROMPTS))
    assert not sched.requests, "client must prune completed bookkeeping"
    for out, prompt in zip(outs, PROMPTS):
        ids = eng.tokenizer.encode(prompt)[-(sched.max_len - 8):]
        # greedy sampling ignores the rid key, so one serial reference
        # per prompt covers whatever rid the thread's submission drew
        ref = eng.generate_ids(ids, 8, cache_len=sched.max_len)
        assert out.token_ids == ref.token_ids
    assert monitor.engine_peak_live >= 2, "threads should share the batch"


# ---------------------------------------------------------------------------
# registry + session integration


def test_registry_resolves_all_builtin_backends():
    names = llm_backend_names()
    assert names[:3] == ["oracle", "jax", "jax-batched"]
    for n in names:
        rs = resolve_llm_backend(n)
        assert rs.capabilities.name == n
    caps = resolve_llm_backend("jax-batched").capabilities
    assert caps.real_model and caps.batched and caps.n_slots >= 1


def test_unknown_backend_lists_registered():
    with pytest.raises(KeyError, match="oracle"):
        resolve_llm_backend("gpt-4o-mini")


def test_register_variant_and_fingerprint():
    @register_llm_backend("jax-test-variant", arch="qwen1.5-4b", n_slots=2)
    class _Variant(JaxServing):
        name = "jax-test-variant"

    spec = RunSpec("web_search", "quantum", "agentx", llm="jax-test-variant")
    base = RunSpec("web_search", "quantum", "agentx", llm="jax")
    oracle = RunSpec("web_search", "quantum", "agentx")
    fps = {spec_fingerprint(s) for s in (spec, base, oracle)}
    assert len(fps) == 3, "serving capabilities must address the cache"


def test_jax_batched_end_to_end_agent_run():
    """The full agent loop with the slot-batched engine as its LLM
    endpoint, selected purely by registry name."""
    reset_llm_backends()
    monitor = RunMonitor()
    get_llm_backend("jax-batched").subscribe(monitor)
    r = Session(on_event=monitor).execute(
        RunSpec("web_search", "quantum", "react", llm="jax-batched"))
    assert r.success
    assert r.trace.agent_invocations >= 3
    assert monitor.engine_steps > 0, "completions must go through the batch"
    assert monitor.engine_tokens > 0
    reset_llm_backends()


def test_oracle_path_stays_jax_free():
    """Registry resolution and a full oracle run must not pull the JAX
    stack (serving exports are lazy; api defers engine imports)."""
    import subprocess
    import sys
    code = (
        "import sys\n"
        "from repro.apps.session import RunSpec, Session\n"
        "r = Session().execute(RunSpec('web_search', 'quantum', 'agentx'))\n"
        "assert r.trace.agent_invocations >= 1\n"
        "assert 'jax' not in sys.modules, 'oracle run imported jax'\n"
    )
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr[-2000:]


def test_run_service_carries_llm_field():
    """run/execute plumbs RunSpec.llm symmetrically with deployment."""
    from repro.env.world import World
    from repro.faas.deployments import RunServiceClient
    from repro.faas.platform import FaaSPlatform
    world = World(seed=0)
    client = RunServiceClient(FaaSPlatform(world))
    out = client.execute("web_search", "quantum", "react", llm="oracle")
    assert out["success"] in (True, False)
    assert out["input_tokens"] > 0


def test_oracle_runs_identical_across_llm_field_default():
    """Registry-resolved oracle == the historical hardwired oracle."""
    a = Session().execute(RunSpec("web_search", "quantum", "agentx", seed=2))
    b = Session().execute(RunSpec("web_search", "quantum", "agentx", seed=2,
                                  llm="oracle"))
    assert a.success == b.success
    assert a.trace.input_tokens == b.trace.input_tokens
    assert a.total_latency == pytest.approx(b.total_latency)


# ---------------------------------------------------------------------------
# serving-side events + disk cache


def test_engine_stepped_wire_roundtrip():
    ev = EngineStepped(t=3.0, live=2, queued=5, generated=2)
    assert from_wire(to_wire(ev)) == ev


def test_run_monitor_sees_engine_occupancy():
    eng = _parity_engine("tinyllama-1.1b")
    monitor = RunMonitor()
    sched = BatchScheduler(eng, n_slots=2, max_len=64)
    sched.subscribe(monitor)
    for p in PROMPTS[:3]:
        sched.submit(p, max_new=4)
    results = sched.drain()
    snap = monitor.snapshot()
    assert snap["engine_steps"] == sched._steps
    assert snap["engine_peak_live"] == 2
    assert snap["engine_tokens"] == sum(
        r.new_tokens for r in results.values()) - len(results)  # prefill tok
    # engine_live reports occupancy DURING the last step (>=1: something
    # finished in it); the drained scheduler itself is idle
    assert 1 <= snap["engine_live"] <= 2
    assert sched.occupancy() == 0 and not sched.has_work()


def test_run_cache_persists_to_disk():
    spec = RunSpec("web_search", "quantum", "agentx", seed=4)
    with tempfile.TemporaryDirectory() as d:
        warm = RunCache(cache_dir=d)
        r1 = Session(cache=warm).execute(spec)
        assert warm.stats()["misses"] == 1

        cold = RunCache(cache_dir=d)      # fresh process simulation
        assert len(cold) == 1
        r2 = Session(cache=cold).execute(spec)
        assert cold.stats() == {"entries": 1, "hits": 1, "misses": 0}
        assert r2.success == r1.success
        assert r2.total_latency == pytest.approx(r1.total_latency)
        assert r2.trace.input_tokens == r1.trace.input_tokens
        assert r2.trace.tool_invocations == r1.trace.tool_invocations
        assert len(r2.extras["events"]) == len(r1.extras["events"])
        assert r2.artifact == r1.artifact


def test_score_run_on_disk_replayed_result():
    """Disk entries drop World/policy extras; score_run rebuilds the
    deterministic pair and scores identically to the warm path."""
    from repro.apps.session import score_run
    spec = RunSpec("web_search", "quantum", "agentx", seed=1)
    with tempfile.TemporaryDirectory() as d:
        r1 = Session(cache=RunCache(cache_dir=d)).execute(spec)
        warm_score = score_run(r1)
        r2 = Session(cache=RunCache(cache_dir=d)).execute(spec)
        assert "world" not in r2.extras   # genuinely replayed from disk
        cold_score = score_run(r2)
        assert cold_score.attributes == warm_score.attributes


def test_run_cache_skips_corrupt_disk_entries():
    with tempfile.TemporaryDirectory() as d:
        with open(f"{d}/deadbeef.json", "w") as f:
            f.write("{not json")
        with open(f"{d}/readme.txt", "w") as f:
            f.write("ignore me")
        cache = RunCache(cache_dir=d)
        assert len(cache) == 0
