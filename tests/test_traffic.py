"""Traffic subsystem: asyncio virtual-clock driver, fault injection with
retry/hedge, SLO benchmarking.

The three acceptance contracts:

  (a) the asyncio driver completes >= 1000 virtual-clock runs in ONE
      process with per-run results bit-identical to serial
      ``Session.execute``;
  (b) at a 20% transient-error rate with ``RetryPolicy`` enabled, every
      run recovers to its no-fault baseline (success AND tokens) while
      ``ToolRetried`` events account for every injected fault;
  (c) ``benchmarks/traffic.py`` emits a well-formed
      ``BENCH_traffic.json`` with success-rate / latency-percentile /
      cost sections per scenario.
"""
import asyncio
import json

import pytest

from repro.apps.session import RunSpec, Session
from repro.core.events import RunHedged, ToolRetried
from repro.core.policies import HedgePolicy, RetryPolicy
from repro.traffic import (FaultPlan, FaultStats, Scenario, SLOTarget,
                           TrafficDriver, VirtualTimeline, Workload,
                           aggregate_report, drive_specs,
                           register_fault_plan)

WEB = [Scenario(f"web/{inst}/{pat}", "web_search", inst, pat,
                weight=1.0)
       for inst in ("quantum", "edge", "materials")
       for pat in ("agentx", "react", "magentic")]


@pytest.fixture(autouse=True)
def _clean_fault_registrations():
    """Fault-injection twins register into the global deployment
    registry; drop whatever a test added so registry-listing assertions
    elsewhere (e.g. test_deployment_api) hold in any run order."""
    from repro.faas.deployments import (deployment_names,
                                        unregister_deployment)
    before = set(deployment_names())
    yield
    for name in set(deployment_names()) - before:
        unregister_deployment(name)


# ---------------------------------------------------------------------------
# workload generation


def test_arrivals_deterministic_and_ordered():
    wl = Workload(scenarios=tuple(WEB), arrival="poisson", rate=3.0,
                  n_requests=50, seed=4)
    a1, a2 = wl.arrivals(), wl.arrivals()
    assert [(a.t, a.spec) for a in a1] == [(a.t, a.spec) for a in a2]
    assert all(x.t <= y.t for x, y in zip(a1, a1[1:]))
    assert len({a.spec.seed for a in a1}) == 50     # unique per-run seeds


def test_arrival_processes_cover_modes():
    for mode in ("poisson", "bursty", "uniform"):
        wl = Workload(scenarios=tuple(WEB), arrival=mode, rate=2.0,
                      n_requests=30, seed=1)
        arr = wl.arrivals()
        assert len(arr) == 30
        assert arr[-1].t > 0
    with pytest.raises(ValueError):
        Workload(arrival="closed").arrivals()
    with pytest.raises(ValueError):
        Workload(arrival="nope").arrivals()


# ---------------------------------------------------------------------------
# the virtual timeline


def test_virtual_timeline_interleaves_deterministically():
    log = []

    async def task(tl, name, dts):
        for dt in dts:
            await tl.sleep(dt)
            log.append((name, tl.now()))
        tl.unregister()

    async def main():
        tl = VirtualTimeline()
        tl.register()
        tl.register()
        await asyncio.gather(task(tl, "a", [1.0, 2.0, 0.5]),
                             task(tl, "b", [0.5, 0.7, 5.0]))
        return tl.now()

    end = asyncio.run(main())
    assert log == [("b", 0.5), ("a", 1.0), ("b", 1.2), ("a", 3.0),
                   ("a", 3.5), ("b", 6.2)]
    assert end == 6.2


def test_virtual_semaphore_fifo_queueing():
    order = []

    async def main():
        tl = VirtualTimeline()
        sem = tl.semaphore(1)

        async def worker(i):
            await tl.sleep(i * 0.1)     # staggered arrivals
            await sem.acquire()
            order.append(i)
            await tl.sleep(10.0)        # hold the slot
            sem.release()
            tl.unregister()

        for _ in range(3):
            tl.register()
        await asyncio.gather(*[worker(i) for i in range(3)])
        return tl.now()

    end = asyncio.run(main())
    assert order == [0, 1, 2]
    assert end == pytest.approx(30.0, abs=1e-9)


# ---------------------------------------------------------------------------
# (a) >= 1000 interleaved runs, bit-identical to serial


def test_driver_1000_runs_bit_identical_to_serial():
    wl = Workload(scenarios=tuple(WEB), arrival="poisson", rate=20.0,
                  n_requests=1000, seed=0)
    report = TrafficDriver(Session()).run(wl)
    assert len(report.records) == 1000
    # thousands of runs interleave: the timeline must actually overlap them
    assert report.peak_concurrency() > 50
    assert report.virtual_s > report.wall_s   # a "day" replays in seconds

    serial = Session()
    for rec in report.records:
        base = serial.execute(rec.spec)
        assert rec.result.success == base.success
        assert rec.result.total_latency == base.total_latency
        assert rec.result.trace.input_tokens == base.trace.input_tokens
        assert rec.result.trace.output_tokens == base.trace.output_tokens
        assert rec.result.artifact == base.artifact
        assert rec.result.failure_reason == base.failure_reason
        # uncapped: timeline completion composes exactly
        assert rec.end == pytest.approx(
            rec.arrival + rec.result.total_latency, abs=1e-6)
        assert rec.queue_wait == 0.0


def test_execute_many_async_matches_serial_order():
    specs = [RunSpec("web_search", "quantum", "react", seed=i)
             for i in range(12)]
    session = Session()
    got = asyncio.run(session.execute_many_async(
        specs, arrivals=[0.5 * i for i in range(12)], max_concurrency=3))
    want = [Session().execute(s) for s in specs]
    assert [r.total_latency for r in got] == \
        [r.total_latency for r in want]
    assert [r.success for r in got] == [r.success for r in want]


def test_capacity_cap_produces_queueing():
    wl = Workload(scenarios=tuple(WEB), arrival="poisson", rate=10.0,
                  n_requests=40, seed=2)
    capped = TrafficDriver(Session(), max_concurrency=2).run(wl)
    assert capped.peak_concurrency() <= 2
    waits = [r.queue_wait for r in capped.records]
    assert max(waits) > 0
    for r in capped.records:   # wait + run compose exactly
        assert r.end == pytest.approx(
            r.start + r.result.total_latency, abs=1e-6)


def test_closed_loop_deterministic():
    wl = Workload(scenarios=tuple(WEB), arrival="closed", users=4,
                  n_requests=12, seed=5, think_s=3.0)
    r1 = TrafficDriver(Session()).run(wl)
    r2 = TrafficDriver(Session()).run(wl)
    assert len(r1.records) == 12
    assert [(r.arrival, r.end, r.result.success) for r in r1.records] == \
        [(r.arrival, r.end, r.result.success) for r in r2.records]


# ---------------------------------------------------------------------------
# (b) fault injection + retry recovers the baseline


def _web_specs(deployment, n, pattern="agentx"):
    return [RunSpec("web_search", "quantum", pattern, deployment, seed=i)
            for i in range(n)]


def test_fault_injection_20pct_retry_recovers_baseline():
    stats = register_fault_plan(
        "local+t20", "local",
        FaultPlan(transient_rate=0.2, first_call_cold=False, seed=7))
    stats.reset()   # other tests may share the registration
    n = 60
    base = Session()
    resilient = Session(retry=RetryPolicy(max_attempts=8, backoff_s=0.2))
    retried = 0
    for pattern in ("agentx", "react"):
        for sb, sf in zip(_web_specs("local", n, pattern),
                          _web_specs("local+t20", n, pattern)):
            rb = base.execute(sb)
            rf = resilient.execute(sf)
            # per-run recovery (stronger than rate equality): identical
            # success, decisions (tokens) and artifact
            assert rf.success == rb.success
            assert rf.trace.output_tokens == rb.trace.output_tokens
            assert rf.artifact == rb.artifact
            retried += sum(isinstance(e, ToolRetried)
                           for e in rf.extras["events"])
    snap = stats.snapshot()
    assert snap["errors"] > 100          # the 20% rate actually bit
    # every injected fault is accounted for by a ToolRetried event
    assert retried == snap["errors"]


def test_faults_without_retry_hurt_success():
    stats = register_fault_plan(
        "local+t20nr", "local",
        FaultPlan(transient_rate=0.2, first_call_cold=False, seed=7))
    stats.reset()
    n = 40
    base_ok = sum(Session().execute(s).success
                  for s in _web_specs("local", n))
    faulted = [Session().execute(s) for s in _web_specs("local+t20nr", n)]
    assert stats.snapshot()["errors"] > 0
    assert sum(r.success for r in faulted) < base_ok
    # and no ToolRetried events without a policy
    assert all(not any(isinstance(e, ToolRetried) for e in r.extras["events"])
               for r in faulted)


def test_fault_world_alias_keeps_environment_identical():
    from repro.apps.session import stable_world_seed
    register_fault_plan("local+alias", "local", FaultPlan())
    s_clean = RunSpec("web_search", "edge", "react", "local", seed=3)
    s_fault = RunSpec("web_search", "edge", "react", "local+alias", seed=3)
    assert stable_world_seed(s_clean) == stable_world_seed(s_fault)


def test_cold_start_hedging_cuts_tail_latency():
    plan = FaultPlan(cold_start_rate=0.5, cold_start_s=30.0,
                     first_call_cold=False, seed=11)
    register_fault_plan("local+cold", "local", plan)
    spec = RunSpec("web_search", "quantum", "react", "local+cold", seed=1)
    slow = Session().execute(spec)
    hedged = Session(hedge=HedgePolicy(hedge_after_s=5.0)).execute(spec)
    hedges = [e for e in hedged.extras["events"] if isinstance(e, RunHedged)]
    assert hedges, "cold starts at 30s past a 5s deadline must hedge"
    assert hedged.total_latency < slow.total_latency
    # decisions are untouched: hedging trades cost for latency only
    assert hedged.trace.output_tokens == slow.trace.output_tokens
    assert all(e.saved_s >= 0 for e in hedges)


def test_throttle_errors_are_retryable():
    stats = register_fault_plan(
        "local+throttle", "local",
        FaultPlan(throttle_rate=0.3, throttle_delay_s=0.5,
                  first_call_cold=False, seed=2))
    stats.reset()
    session = Session(retry=RetryPolicy(max_attempts=8, backoff_s=0.1))
    for i in range(10):
        r = session.execute(RunSpec("web_search", "quantum", "react",
                                    "local+throttle", seed=i))
        b = Session().execute(RunSpec("web_search", "quantum", "react",
                                      seed=i))
        assert r.success == b.success
    assert stats.snapshot()["throttled"] > 0


def test_driver_with_faults_and_retry_matches_clean_driver():
    """The full stack: faulty workload through the asyncio driver with
    retries == clean workload, run for run."""
    register_fault_plan("local+drv", "local",
                        FaultPlan(transient_rate=0.2,
                                  first_call_cold=False, seed=9))
    mix_clean = tuple(WEB[:3])
    mix_fault = tuple(Scenario(s.name, s.app, s.instance, s.pattern,
                               "local+drv", s.llm, s.priority, s.weight)
                      for s in mix_clean)
    wl = dict(arrival="poisson", rate=5.0, n_requests=60, seed=3)
    clean = TrafficDriver(Session()).run(
        Workload(scenarios=mix_clean, **wl))
    fault = TrafficDriver(
        Session(retry=RetryPolicy(max_attempts=8, backoff_s=0.2))).run(
        Workload(scenarios=mix_fault, **wl))
    assert [r.result.success for r in clean.records] == \
        [r.result.success for r in fault.records]
    assert [r.result.trace.output_tokens for r in clean.records] == \
        [r.result.trace.output_tokens for r in fault.records]
    # retries add latency, never remove it
    assert all(f.latency >= c.result.total_latency - 1e-9
               for c, f in zip(clean.records, fault.records))


# ---------------------------------------------------------------------------
# (c) SLO aggregation + the benchmark artifact


def test_slo_aggregate_sections():
    wl = Workload(scenarios=tuple(WEB), arrival="poisson", rate=5.0,
                  n_requests=40, seed=6)
    agg = aggregate_report(TrafficDriver(Session()).run(wl),
                           SLOTarget(latency_s=100.0))
    assert set(agg) == {"scenarios", "overall", "replay"}
    for name, a in list(agg["scenarios"].items()) + [("_", agg["overall"])]:
        assert 0.0 <= a["success_rate"] <= 1.0
        for dist in ("latency_s", "ttft_s", "queue_wait_s"):
            assert set(a[dist]) == {"p50", "p95", "p99", "mean", "max"}
            assert a[dist]["p50"] <= a[dist]["p95"] <= a[dist]["max"]
        assert a["cost_usd"]["total_mean"] > 0
        assert 0.0 <= a["slo"]["latency_attainment"] <= 1.0
    assert agg["replay"]["speedup"] > 1
    assert sum(a["n"] for a in agg["scenarios"].values()) == 40


def test_bench_traffic_artifact_well_formed(tmp_path):
    from benchmarks.traffic import measure
    rec = measure(n_requests=30, rate=4.0, seed=1)
    # JSON round-trip: the artifact must serialize cleanly
    path = tmp_path / "BENCH_traffic.json"
    path.write_text(json.dumps(rec, indent=2))
    loaded = json.loads(path.read_text())
    assert set(loaded) >= {"workload", "slo", "scenarios", "overall",
                           "replay", "fault_injection"}
    for name, a in loaded["scenarios"].items():
        assert {"success_rate", "latency_s", "ttft_s",
                "cost_usd"} <= set(a), name
    fi = loaded["fault_injection"]
    assert fi["with_retry"]["retry_accounts_for_all_faults"] is True
    sr = fi["success_rate"]
    # the robustness headline: faults hurt, retry+hedge recovers
    assert sr["faulted"] < sr["clean"]
    assert sr["recovered"] == pytest.approx(sr["clean"], abs=1e-9)


# ---------------------------------------------------------------------------
# async pump against the real batched engine


def test_generate_async_parity_with_serial_engine():
    from repro.configs import get_config
    from repro.serving import BatchScheduler, Engine
    from repro.serving.scheduler import EngineClient
    cfg = get_config("tinyllama-1.1b").reduced()
    engine = Engine(cfg, temperature=0.0)
    client = EngineClient(BatchScheduler(engine, n_slots=4, max_len=64))
    # short prompts: submit() clips to the max_len - max_new tail, which
    # would desync the serial comparison for overlong prompts
    prompts = [f"request {i}: agents" for i in range(6)]

    async def fan_out():
        return await asyncio.gather(
            *[client.generate_async(p, max_new_tokens=6) for p in prompts])

    outs = asyncio.run(fan_out())
    for i, (p, out) in enumerate(zip(prompts, outs)):
        serial = engine.generate_ids(engine.tokenizer.encode(p), 6,
                                     rid=i, cache_len=64)
        assert out.token_ids == serial.token_ids
