"""A2A protocol tests + sharding-rule unit tests (no multi-device mesh
needed — rules are pure functions over a 1-device mesh's axis names)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.env.world import World
from repro.mcp.a2a import (A2AClient, AgentCard, AgentSkill, A2AServer,
                           expose_app_as_agent)


# --- A2A ------------------------------------------------------------------


def _server(world, handler=None):
    card = AgentCard("test-agent", "testing", "https://x/agent",
                     [AgentSkill("echo", "Echo", "echoes the message")])
    return A2AServer(card, world,
                     {"echo": handler or
                      (lambda m: {"text": m.upper(), "success": True})})


def test_agent_card_wire_format():
    world = World(0)
    card = _server(world).agent_card()
    assert card["name"] == "test-agent"
    assert card["skills"][0]["id"] == "echo"
    assert "securitySchemes" in card


def test_task_lifecycle():
    world = World(0)
    client = A2AClient(world)
    server = _server(world)
    client.discover(server)
    task = client.delegate("test-agent", "echo", "hello")
    assert task.status == "completed"
    assert task.artifacts[0]["text"] == "HELLO"
    assert server.get_task(task.task_id) is task


def test_unknown_skill_fails_gracefully():
    world = World(0)
    server = _server(world)
    task = server.send_task("nope", "x")
    assert task.status == "failed"
    assert not task.artifacts
    # the failure is recorded in the task history for the caller to read
    assert task.history[-1]["role"] == "agent"
    assert "unknown skill 'nope'" in task.history[-1]["text"]
    assert server.get_task(task.task_id) is task


def test_handler_crash_is_failed_task():
    world = World(0)
    def boom(m):
        raise RuntimeError("remote crash")
    task = _server(world, boom).send_task("echo", "x")
    assert task.status == "failed"
    assert not task.artifacts
    # history keeps both the request and the crash report
    assert [h["role"] for h in task.history] == ["user", "agent"]
    assert "remote crash" in task.history[-1]["text"]


def test_expose_app_as_agent_end_to_end():
    world = World(1)
    client = A2AClient(world)
    agent = expose_app_as_agent(world, "web_search", "react", "local",
                                "https://x/web")
    client.discover(agent)
    task = client.delegate(agent.card.name, "web_search",
                           "look into quantum computing")
    assert task.status == "completed"
    assert len(task.artifacts[0]["text"]) > 100
    assert world.clock.now() > 10   # remote latency billed to caller


# --- sharding rules ---------------------------------------------------------


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _spec(mesh, shape, *names):
    from repro.launch.sharding import param_spec

    class FakeKey:
        def __init__(self, k):
            self.key = k
    path = tuple(FakeKey(n) for n in names)
    leaf = jax.ShapeDtypeStruct(shape, jnp.float32)
    return param_spec(path, leaf, mesh)


def test_param_rules_2d_fsdp_tp(mesh):
    assert _spec(mesh, (80, 512, 2048), "layers", "mlp", "w_gate") == \
        P(None, "data", "model")
    assert _spec(mesh, (80, 2048, 512), "layers", "mlp", "w_down") == \
        P(None, "model", "data")
    assert _spec(mesh, (1000, 512), "embed") == P("model", "data")


def test_expert_rules(mesh):
    assert _spec(mesh, (32, 16, 512, 128), "layers", "moe", "experts",
                 "w_gate") == P(None, "model", "data", None)


def test_opt_state_strips_mv_prefix(mesh):
    assert _spec(mesh, (80, 512, 2048), "m", "layers", "mlp", "w_gate") == \
        P(None, "data", "model")


def test_norms_replicated(mesh):
    assert _spec(mesh, (80, 512), "layers", "attn_norm") == P(None, None)
    # but the SSM gated-norm (d_inner-sized) shards over model
    assert _spec(mesh, (48, 1024), "layers", "ssm", "norm") == P(None, "model")


def test_indivisible_dims_not_sharded():
    mesh16 = jax.make_mesh((1, 1), ("data", "model"))
    # emulate divisibility logic with a fake 16-wide axis via direct check:
    from repro.launch.sharding import param_spec

    class FakeKey:
        def __init__(self, k):
            self.key = k
    leaf = jax.ShapeDtypeStruct((50280, 1024), jnp.float32)
    spec = param_spec((FakeKey("embed"),), leaf, mesh16)
    # vocab 50280 divisible by 1 -> sharded on the 1-sized axis is fine;
    # the 16-way guard is covered by the production dry-run artifacts.
    assert spec == P("model", "data")


def test_activation_policy_shapes():
    from repro.configs import get_config
    from repro.configs.base import INPUT_SHAPES
    from repro.launch.sharding import make_activation_policy
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    pol = make_activation_policy(get_config("qwen2-72b"),
                                 INPUT_SHAPES["train_4k"], mesh)
    assert pol["tokens"] == P(("data",), None)
    # long_500k batch=1: unsharded on >1-sized data axes (trivially
    # shardable on this 1-device mesh)
    pol2 = make_activation_policy(get_config("qwen2-72b"),
                                  INPUT_SHAPES["long_500k"], mesh)
    assert pol2["tokens"][0] in (None, ("data",), "data")


def test_variant_shardings_shapes():
    from repro.launch.variants import param_shardings_variant, VARIANTS
    from repro.models.params import abstract_params
    from repro.configs import get_config
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    abstract = abstract_params(get_config("tinyllama-1.1b").reduced())
    for v in VARIANTS:
        sh = param_shardings_variant(abstract, mesh, v)
        assert jax.tree_util.tree_structure(sh) == \
            jax.tree_util.tree_structure(abstract), v
