"""Telemetry subsystem: deterministic metrics core, event bridge,
byte-identical exports, SLO burn-rate alerts, jit profiling hooks.

The acceptance contracts this file pins:

  (a) two virtual-clock replays of the same seeded workload produce
      BYTE-identical Prometheus (and OTLP JSON) exports;
  (b) the bridge is lossless: every folded event is accounted in
      ``repro_events_total`` and per-family counts reconcile against
      the raw stream;
  (c) telemetry is free when off: attaching a bridge does not perturb a
      run — the event stream and result are bit-identical to a bare
      session's;
  (d) histogram exemplars carry the SAME span ids ``fold_spans``
      assigns the stream, so a latency sample links into its span tree.
"""
import json

import pytest

from repro.apps.session import RunSpec, Session
from repro.core.events import (LLMCompleted, RunCompleted, RunStarted,
                               SloAlertFired, ToolInvoked, events_from_wire,
                               events_to_wire, to_wire)
from repro.core.metrics import LLMEvent
from repro.telemetry import (DEFAULT_LATENCY_BUCKETS, EventMetricsBridge,
                             JitProfiler, MetricsRegistry, SloMonitor,
                             export_otlp_metrics_json, fold_report,
                             log_buckets, parse_prometheus,
                             render_prometheus, to_otlp_metrics)
from repro.tenancy.tracing import fold_spans
from repro.traffic import SLOTarget, Scenario, TrafficDriver, Workload

SCENARIOS = tuple(
    Scenario(f"web/{inst}/{pat}", "web_search", inst, pat, weight=1.0)
    for inst in ("quantum", "edge") for pat in ("agentx", "react"))


def _workload(n=24, seed=0):
    return Workload(scenarios=SCENARIOS, arrival="poisson", rate=10.0,
                    n_requests=n, seed=seed)


def _fold_workload(n=24, seed=0):
    """One seeded oracle workload folded into a fresh registry."""
    report = TrafficDriver(Session()).run(_workload(n, seed))
    registry = MetricsRegistry()
    bridge = EventMetricsBridge(registry)
    fold_report(bridge, report)
    return report, registry


# ---------------------------------------------------------------------------
# metrics core


def test_log_buckets_pattern():
    assert log_buckets(0.001, 2) == [0.001, 0.0025, 0.005,
                                     0.01, 0.025, 0.05]
    assert DEFAULT_LATENCY_BUCKETS[0] == 0.001
    assert DEFAULT_LATENCY_BUCKETS == tuple(sorted(DEFAULT_LATENCY_BUCKETS))


def test_counter_labels_and_monotonicity():
    r = MetricsRegistry()
    c = r.counter("x_total", "help")
    c.inc(tool="search")
    c.inc(2.0, tool="search")
    c.inc(tool="fetch")
    assert c.value(tool="search") == 3.0
    assert c.value(tool="fetch") == 1.0
    assert c.value(tool="never") == 0.0
    assert c.total() == 4.0
    with pytest.raises(ValueError):
        c.inc(-1.0, tool="search")


def test_gauge_set_add_max():
    r = MetricsRegistry()
    g = r.gauge("g", "help")
    g.set(3.0)
    g.add(-1.0)
    assert g.value() == 2.0
    g.max_of(7.0)
    g.max_of(4.0)
    assert g.value() == 7.0


def test_histogram_bucket_edge_cases():
    """Prometheus ``le`` semantics: an observation EQUAL to a bound
    lands in that bound's bucket; past the last bound lands in +Inf."""
    r = MetricsRegistry()
    h = r.histogram("h", "help", buckets=(1.0, 2.5, 5.0))
    for v in (1.0, 2.5, 5.0, 5.0001, 0.0):
        h.observe(v)
    snap = h.snapshot()
    # counts per bucket: <=1.0 gets {1.0, 0.0}; <=2.5 gets {2.5};
    # <=5.0 gets {5.0}; +Inf gets {5.0001}
    assert snap["counts"] == [2, 1, 1, 1]
    assert snap["count"] == 5
    assert snap["sum"] == pytest.approx(13.5001)


def test_label_cardinality_and_ordering():
    """Label order never matters; distinct values make distinct series;
    labelsets iterate sorted (the determinism the exports rest on)."""
    r = MetricsRegistry()
    c = r.counter("c_total", "help")
    c.inc(a="1", b="2")
    c.inc(b="2", a="1")        # same series, different kwarg order
    c.inc(a="1", b="3")
    assert c.value(a="1", b="2") == 2.0
    assert len(c.labelsets()) == 2
    assert c.labelsets() == sorted(c.labelsets())
    assert r.label_values("c_total", "b") == ["2", "3"]


def test_registry_kind_mismatch_raises():
    r = MetricsRegistry()
    r.counter("m", "help")
    assert r.counter("m") is r.get("m")     # re-request: same family
    with pytest.raises(TypeError):
        r.gauge("m")


def test_scope_stamps_const_labels():
    r = MetricsRegistry()
    eng = r.scope(layer="engine")
    eng.counter("s_total", "help").inc(2.0, kind="decode")
    assert r.get("s_total").value(layer="engine", kind="decode") == 2.0
    # reserved call params pass through, they never become labels
    eng.histogram("s_lat", "help", buckets=(1.0,)).observe(
        0.5, t=3.0, exemplar={"run": "1"}, kind="decode")
    series = r.get("s_lat").series
    assert list(series.values())[0].exemplars[0][0] == {"run": "1"}
    assert dict(list(series)[0]) == {"kind": "decode", "layer": "engine"}


# ---------------------------------------------------------------------------
# exports


def _toy_registry():
    r = MetricsRegistry(clock=lambda: 12.5)
    r.counter("repro_demo_total", "demo counter").inc(3, tool="search")
    r.gauge("repro_demo_gauge", "demo gauge").set(1.5)
    r.histogram("repro_demo_seconds", "demo hist", unit="s",
                buckets=(0.1, 1.0)).observe(
                    0.5, exemplar={"run": "1", "span": "%016x" % 2})
    return r


def test_prometheus_text_renders_and_parses():
    r = _toy_registry()
    text = render_prometheus(r)
    assert "# TYPE repro_demo_total counter" in text
    assert "# TYPE repro_demo_seconds histogram" in text
    assert render_prometheus(r) == text          # stable
    parsed = parse_prometheus(text)
    assert parsed["repro_demo_total"]['{tool="search"}'] == 3.0
    assert parsed["repro_demo_gauge"][""] == 1.5
    # cumulative le buckets + +Inf + _sum/_count
    assert parsed["repro_demo_seconds_bucket"]['{le="+Inf"}'] == 1.0
    assert parsed["repro_demo_seconds_count"][""] == 1.0


def test_otlp_metrics_shape_and_determinism():
    r = _toy_registry()
    doc = to_otlp_metrics(r, service="repro-test")
    rm = doc["resourceMetrics"][0]
    names = [m["name"] for m in rm["scopeMetrics"][0]["metrics"]]
    assert names == sorted(names)
    assert "repro_demo_seconds" in names
    hist = [m for m in rm["scopeMetrics"][0]["metrics"]
            if m["name"] == "repro_demo_seconds"][0]
    dp = hist["histogram"]["dataPoints"][0]
    assert dp["count"] == "1" and len(dp["exemplars"]) == 1
    assert export_otlp_metrics_json(r) == export_otlp_metrics_json(r)
    json.loads(export_otlp_metrics_json(r))      # valid JSON


# ---------------------------------------------------------------------------
# the bridge: losslessness, wire parity, exemplar linkage


def _one_run(seed=3):
    spec = RunSpec("web_search", "quantum", "agentx", seed=seed)
    result = Session().execute(spec)
    return result, list(result.extras["events"])


def test_bridge_losslessness():
    """Every event lands in repro_events_total and per-family counts
    reconcile against the raw stream — no accounting escapes."""
    _, events = _one_run()
    registry = MetricsRegistry()
    EventMetricsBridge(registry).feed(events)
    assert registry.total("repro_events_total") == len(events)
    assert registry.total("repro_tool_calls_total") == \
        sum(isinstance(e, ToolInvoked) for e in events)
    assert registry.total("repro_llm_calls_total") == \
        sum(isinstance(e, LLMCompleted) for e in events)
    assert registry.get("repro_llm_latency_seconds") is not None
    assert registry.total("repro_llm_latency_seconds") == \
        registry.total("repro_llm_calls_total")


def test_wire_replay_folds_identically():
    """In-process stream and its wire round-trip write the identical
    registry — byte-identical Prometheus text."""
    _, events = _one_run()
    r1, r2 = MetricsRegistry(), MetricsRegistry()
    EventMetricsBridge(r1).feed(events)
    EventMetricsBridge(r2).feed(events_from_wire(events_to_wire(events)))
    assert render_prometheus(r1) == render_prometheus(r2)
    assert export_otlp_metrics_json(r1) == export_otlp_metrics_json(r2)


def test_exemplars_carry_fold_spans_ids():
    """A latency exemplar's span id is the id fold_spans assigns the
    same stream — histograms link into the span tree."""
    _, events = _one_run()
    registry = MetricsRegistry()
    EventMetricsBridge(registry).feed(events)
    tree_ids = {s.span_id for root in fold_spans(events)
                for s in root.walk()}
    exemplar_ids = set()
    for fam in ("repro_llm_latency_seconds", "repro_tool_latency_seconds"):
        for series in registry.get(fam).series.values():
            for labels, _v, _t in series.exemplars.values():
                exemplar_ids.add(labels["span"])
    assert exemplar_ids, "expected latency exemplars"
    assert exemplar_ids <= tree_ids


def test_telemetry_off_is_free():
    """(c): a session with a bridge attached produces the bit-identical
    event stream and result a bare session does — telemetry never
    perturbs the run it observes."""
    spec = RunSpec("web_search", "edge", "react", seed=11)
    bare = Session().execute(spec)
    bridge = EventMetricsBridge()
    observed = Session(on_event=bridge).execute(spec)
    assert events_to_wire(observed.extras["events"]) == \
        events_to_wire(bare.extras["events"])
    assert observed.success == bare.success
    assert observed.faas_cost == bare.faas_cost
    assert observed.trace.llm_cost == bare.trace.llm_cost
    # and the bridge saw the run
    assert bridge.registry.total("repro_events_total") == \
        len(bare.extras["events"])


# ---------------------------------------------------------------------------
# (a): byte-identical exports across two virtual replays


def test_two_virtual_replays_byte_identical_export():
    report1, reg1 = _fold_workload(seed=0)
    report2, reg2 = _fold_workload(seed=0)
    text1, text2 = render_prometheus(reg1), render_prometheus(reg2)
    assert text1 == text2
    assert export_otlp_metrics_json(reg1) == export_otlp_metrics_json(reg2)
    # and the key series are actually populated
    parsed = parse_prometheus(text1)
    assert reg1.total("repro_tool_latency_seconds") > 0
    assert reg1.total("repro_run_latency_seconds") == len(report1.records)
    assert any(k.startswith("repro_tool_latency_seconds")
               for k in parsed)


def test_different_seeds_diverge():
    """Sanity for the invariant above: the export is a function of the
    workload, not a constant."""
    _, reg1 = _fold_workload(seed=0)
    _, reg2 = _fold_workload(seed=5)
    assert render_prometheus(reg1) != render_prometheus(reg2)


# ---------------------------------------------------------------------------
# SLO burn-rate alerts


def _slo():
    return SLOTarget(latency_s=10.0, ttft_s=5.0, success_rate=0.9)


def test_burn_rate_windows_and_alert():
    registry = MetricsRegistry()
    fired = []
    mon = SloMonitor(_slo(), window_s=60.0, threshold=2.0,
                     registry=registry, on_alert=fired.append)
    # window 0: all healthy — no alert
    for i in range(4):
        mon.observe(t=10.0 * i, ok=True, latency_s=1.0, ttft_s=0.5)
    # window 1: 2/4 failures => burn = 0.5 / 0.1 = 5.0 >= 2.0
    for i in range(4):
        mon.observe(t=60.0 + 10.0 * i, ok=(i % 2 == 0), latency_s=1.0,
                    ttft_s=0.5)
    mon.finalize()
    success_alerts = [a for a in fired if a.slo == "success"]
    assert len(success_alerts) == 1
    a = success_alerts[0]
    assert a.window_start == 60.0 and a.bad == 2 and a.total == 4
    assert a.burn_rate == pytest.approx(5.0)
    assert a.t == 120.0
    assert registry.get("repro_slo_alerts_total").value(slo="success") == 1
    assert registry.get("repro_slo_burn_rate").value(slo="success") == \
        pytest.approx(5.0)
    assert mon.summary()["by_objective"]["success"] == 1


def test_latency_and_ttft_objectives_share_budget_currency():
    fired = []
    mon = SloMonitor(_slo(), window_s=60.0, threshold=2.0,
                     on_alert=fired.append)
    for i in range(4):
        # all succeed, but half blow the latency target and all blow TTFT
        mon.observe(t=5.0 * i, ok=True,
                    latency_s=99.0 if i % 2 else 1.0, ttft_s=50.0)
    mon.finalize()
    assert {a.slo for a in fired} == {"latency", "ttft"}


def test_min_count_suppresses_thin_windows():
    fired = []
    mon = SloMonitor(_slo(), window_s=60.0, threshold=2.0, min_count=3,
                     on_alert=fired.append)
    mon.observe(t=0.0, ok=False, latency_s=1.0)
    mon.finalize()
    assert fired == []


def test_alert_event_folds_through_bridge():
    """A replayed alert stream lands in repro_slo_alerts_total — alerts
    are first-class events on the wire."""
    alert = SloAlertFired(t=120.0, slo="success", window_start=60.0,
                          window_s=60.0, burn_rate=5.0, threshold=2.0,
                          bad=2, total=4, target=0.9)
    registry = MetricsRegistry()
    EventMetricsBridge(registry).feed([to_wire(alert)])   # wire dicts ok
    assert registry.get("repro_slo_alerts_total").value(slo="success") == 1
    assert registry.total("repro_events_total") == 1


def test_slo_monitor_over_traffic_records_deterministic():
    report = TrafficDriver(Session()).run(_workload(16, seed=2))
    outs = []
    for _ in range(2):
        mon = SloMonitor(SLOTarget(), window_s=30.0, threshold=1.0)
        mon.observe_records(report.records)
        outs.append((len(mon.alerts), mon.summary()))
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# jit profiling hooks


def test_profiler_counts_calls_and_compiles():
    import jax
    import jax.numpy as jnp

    @jax.jit
    def f(x):
        return x * 2.0

    prof = JitProfiler()
    g = prof.wrap("f", f)
    a = g(jnp.ones((4,)))
    b = g(jnp.ones((4,)))          # cached trace
    c = g(jnp.ones((8,)))          # new shape -> recompile
    assert list(a) == [2.0] * 4 and list(b) == [2.0] * 4
    assert list(c) == [2.0] * 8
    s = prof.stats()["f"]
    assert s["calls"] == 3 and s["compiles"] == 2
    assert s["total_s"] >= 0 and s["max_ms"] >= s["min_ms"]
    assert prof.registry.get("repro_jit_calls_total").value(fn="f") == 3
    assert prof.registry.get("repro_jit_compiles_total").value(fn="f") == 2
    assert any("f" in row for row in prof.table())


def test_profiler_keeps_private_registry_by_default():
    """Wall times are nondeterministic, so they must not leak into a
    bridge registry that byte-identical-replay tests compare."""
    bridge = EventMetricsBridge()
    prof = JitProfiler()
    assert prof.registry is not bridge.registry
    shared = JitProfiler(registry=bridge.registry)
    assert shared.registry is bridge.registry


def test_wrap_kernel_ops_rebinds_and_restores():
    from repro import kernels
    from repro.kernels import ops
    prof = JitProfiler()
    originals = {n: getattr(ops, n) for n in prof.KERNEL_OPS
                 if hasattr(ops, n)}
    assert originals, "expected kernel ops to wrap"
    restore = prof.wrap_kernel_ops()
    try:
        for n in originals:
            assert getattr(ops, n).__wrapped__ is originals[n]
            if hasattr(kernels, n):
                assert getattr(kernels, n).__wrapped__ is originals[n]
    finally:
        restore()
    for n, fn in originals.items():
        assert getattr(ops, n) is fn


# ---------------------------------------------------------------------------
# RunMonitor as a view over the registry


def test_run_monitor_is_thin_view_over_registry():
    from repro.core.metrics import ToolEvent
    from repro.serving.engine import RunMonitor
    mon = RunMonitor()
    mon(RunStarted(t=0.0, pattern="agentx", task="t", tenant="acme"))
    mon(LLMCompleted(t=1.0, event=LLMEvent("executor", 100, 50, 1.0, 1.0)))
    mon(ToolInvoked(t=2.0, event=ToolEvent("serper", "google_search",
                                           0.5, False, 2.0)))
    mon(RunCompleted(t=3.0, completed=True, data=None))
    assert mon.runs_started == 1 and mon.runs_completed == 1
    assert mon.llm_calls == 1 and mon.calls_per_agent == {"executor": 1}
    assert mon.input_tokens == 100 and mon.output_tokens == 50
    assert mon.tool_calls == 1 and mon.tool_errors == 1
    assert mon.in_flight == 0
    assert mon.tenants["acme"]["llm_calls"] == 1
    assert mon.tenants["acme"]["tokens"] == 150
    # the same fold is live on the wrapped registry, export-ready
    text = render_prometheus(mon.registry)
    assert 'repro_llm_calls_total{agent="executor"} 1' in text
    snap = mon.snapshot()
    assert snap["runs_started"] == 1
    assert snap["tenants"]["acme"]["completed"] == 1
