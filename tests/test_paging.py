"""Paged KV cache: allocator/prefix-cache units, an invariant-checking
allocator fuzz, and the seeded paged-vs-contiguous parity battery.

Acceptance criteria of the paging tentpole:
  * block allocator refcounting survives randomized alloc / incref /
    decref / fork sequences with invariants checked after EVERY op
    (the failing seed is printed for replay);
  * the content-hashed prefix cache matches only full blocks, caps the
    match so at least one token is freshly prefilled, and LRU-evicts;
  * paged decode is bit-identical to the contiguous scheduler —
    which is bit-identical to serial generation — across GQA/MLA,
    greedy and sampled, whole/bucketed/chunked admission, shared-prefix
    groups, block-boundary lengths, preemption and pool exhaustion;
  * with prefix reuse off the paged event stream matches the
    contiguous one field-for-field modulo the new paging gauges.
"""
import numpy as np
import pytest

from paging_scenarios import (BLOCK, MAX_LEN, assert_parity, gen_scenario,
                              get_engine, run_scenario)
from repro.serving import (BatchScheduler, BlockAllocator, PagingError,
                          PrefixCache, RunMonitor, prefix_block_keys)
from repro.core.events import EngineStepped

# ---------------------------------------------------------------------------
# block allocator


def test_allocator_alloc_free_cycle():
    a = BlockAllocator(4, 8)
    got = [a.alloc() for _ in range(4)]
    assert sorted(got) == [0, 1, 2, 3]
    assert a.alloc() is None and a.free_count == 0 and a.in_use == 4
    assert a.decref(2) is True          # freed
    assert a.alloc() == 2               # FIFO reuse
    a.incref(2)
    assert a.decref(2) is False         # still referenced
    assert a.decref(2) is True


def test_allocator_double_free_raises():
    a = BlockAllocator(2, 8)
    b = a.alloc()
    a.decref(b)
    with pytest.raises(PagingError):
        a.decref(b)


def test_allocator_fork_semantics():
    a = BlockAllocator(2, 8)
    b = a.alloc()
    assert a.fork(b) == (b, False)      # sole owner: no copy
    a.incref(b)
    new, needs_copy = a.fork(b)
    assert needs_copy and new != b      # shared: one ref moves off
    assert a.ref(b) == 1 and a.ref(new) == 1
    a.incref(b)                         # share b again; pool now empty
    assert a.fork(b) is None            # copy needed -> caller must evict


def _check_invariants(a: BlockAllocator, refs: dict):
    held = {b: n for b, n in refs.items() if n > 0}
    assert a.in_use == len(held)
    assert a.free_count + a.in_use == a.n_blocks
    for b, n in held.items():
        assert a.ref(b) == n, f"block {b}: model {n} != allocator {a.ref(b)}"


def test_allocator_fuzz():
    """Randomized op soup; the shadow refcount model and the allocator
    must agree after every single operation."""
    seed = np.random.SeedSequence().entropy % (2 ** 32)
    rng = np.random.default_rng(seed)
    try:
        a = BlockAllocator(12, 8)
        refs: dict = {}
        for _ in range(2000):
            held = [b for b, n in refs.items() if n > 0]
            op = rng.integers(0, 4)
            if op == 0:
                b = a.alloc()
                if b is None:
                    assert a.free_count == 0
                else:
                    assert refs.get(b, 0) == 0
                    refs[b] = 1
            elif op == 1 and held:
                b = int(rng.choice(held))
                a.incref(b)
                refs[b] += 1
            elif op == 2 and held:
                b = int(rng.choice(held))
                freed = a.decref(b)
                refs[b] -= 1
                assert freed == (refs[b] == 0)
            elif op == 3 and held:
                b = int(rng.choice(held))
                got = a.fork(b)
                if refs[b] == 1:
                    assert got == (b, False)
                elif got is None:
                    assert a.free_count == 0
                else:
                    new, needs_copy = got
                    assert needs_copy and refs.get(new, 0) == 0
                    refs[b] -= 1
                    refs[new] = 1
            _check_invariants(a, refs)
    except AssertionError:
        raise AssertionError(f"allocator fuzz failed with seed {seed}")


# ---------------------------------------------------------------------------
# prefix cache


def test_prefix_chain_keys():
    ids = list(range(20))
    keys = prefix_block_keys(ids, 8, "salt")
    assert len(keys) == 2               # only FULL blocks are keyed
    # chained: a diverging first block changes every downstream key
    other = prefix_block_keys([99] + ids[1:], 8, "salt")
    assert keys[0] != other[0] and keys[1] != other[1]
    # same chain, different salt -> disjoint key space
    assert prefix_block_keys(ids, 8, "other")[0] != keys[0]
    # prefix property: shared leading blocks share leading keys
    assert prefix_block_keys(ids[:16] + [500], 8, "salt")[:2] == keys


def test_prefix_cache_match_cap_and_lru():
    a = BlockAllocator(16, 4)
    pc = PrefixCache(a, salt="s")
    ids = list(range(12))
    blocks = [a.alloc() for _ in range(3)]
    pc.insert(ids, blocks)              # caches 3 full blocks
    # exact-length match is capped one block short: the last position
    # must be freshly prefilled for its logits
    n, got = pc.match(ids)
    assert n == 8 and got == blocks[:2]
    n, got = pc.match(ids + [50])       # longer prompt: all 3 usable
    assert n == 12 and got == blocks
    assert pc.match([99, 98, 97, 96])[0] == 0
    # cached blocks are pinned: the insert incref survives our decref
    for b in blocks:
        a.decref(b)
    assert a.in_use == 3
    pc.evict()                          # LRU pop releases the pin
    assert a.in_use == 2 and len(pc) == 2
    s = pc.stats()
    assert s["hits"] == 2 and s["misses"] == 1 and s["tokens_reused"] == 20


# ---------------------------------------------------------------------------
# scheduler-level paging behaviour


def test_paged_scheduler_rejects_bad_geometry():
    eng = get_engine("gqa", 0.0)
    with pytest.raises(ValueError):
        BatchScheduler(eng, n_slots=2, max_len=MAX_LEN, paged_kv=True,
                       block_size=7)   # max_len % block_size != 0
    with pytest.raises(ValueError):
        BatchScheduler(eng, n_slots=2, max_len=MAX_LEN, paged_kv=True,
                       block_size=BLOCK, n_blocks=3)  # < one sequence


def test_paged_exhaustion_requeues_and_recovers():
    """A pool two sequences wide still serves six requests: admission
    failures requeue instead of deadlocking, stats stay coherent."""
    eng = get_engine("gqa", 0.0)
    sched = BatchScheduler(eng, n_slots=2, max_len=MAX_LEN, paged_kv=True,
                           block_size=BLOCK, n_blocks=2 * (MAX_LEN // BLOCK))
    rids = [sched.submit(prompt_ids=[i + 1] * 21, max_new=4)
            for i in range(6)]
    res = sched.drain()
    assert sorted(res) == sorted(rids)
    assert all(len(res[r].token_ids) == 4 for r in rids)
    s = sched.paging_stats()
    # drained: the only live references left are the prefix cache's pins
    assert s["blocks_in_use"] == s["entries"]
    assert s["blocks_free"] + s["blocks_in_use"] == s["n_blocks"]


def test_paged_prefix_hits_and_gauges():
    """Same-prefix admissions hit the prefix cache; EngineStepped
    carries live blocks_in_use and cumulative prefix_hits, and
    RunMonitor aggregates them."""
    eng = get_engine("gqa", 0.0)
    sched = BatchScheduler(eng, n_slots=2, max_len=MAX_LEN, paged_kv=True,
                           block_size=BLOCK)
    mon = RunMonitor()
    events = []
    sched.subscribe(lambda e: (mon(e), events.append(e))
                    if isinstance(e, EngineStepped) else None)
    base = list(range(1, 18))
    for i in range(4):
        sched.submit(prompt_ids=base + [100 + i], max_new=3)
    sched.drain()
    s = sched.paging_stats()
    assert s["hits"] >= 3 and s["tokens_reused"] >= 3 * 16
    assert max(e.blocks_in_use for e in events) > 0
    assert max(e.prefix_hits for e in events) >= 1
    snap = mon.snapshot()
    assert snap["engine_prefix_hits"] >= 3
    assert snap["engine_blocks_in_use"] >= 0


def test_contiguous_emits_zero_paging_gauges():
    """With paging off the new gauges stay at their defaults — the
    wire payload is exactly the pre-paging one."""
    eng = get_engine("gqa", 0.0)
    sched = BatchScheduler(eng, n_slots=2, max_len=MAX_LEN)
    events = []
    sched.subscribe(lambda e: events.append(e)
                    if isinstance(e, EngineStepped) else None)
    sched.submit(prompt_ids=list(range(1, 10)), max_new=3)
    sched.drain()
    assert events
    assert all(e.blocks_in_use == 0 and e.prefix_hits == 0 for e in events)


# ---------------------------------------------------------------------------
# parity battery (seeded-random; the hypothesis suite widens the search)

PARITY_CASES = [
    ("gqa", 0.0, 0, 11),     # greedy, whole-prompt/bucketed admission
    ("gqa", 1.0, 0, 12),     # sampled
    ("gqa", 1.0, 8, 13),     # sampled + chunked prefill
    ("mla", 0.0, 0, 14),     # MLA cache family, greedy
    ("mla", 1.0, 8, 15),     # MLA sampled + chunked
]


@pytest.mark.parametrize("arch,temp,chunk,seed", PARITY_CASES,
                         ids=[f"{a}-t{t}-c{c}" for a, t, c, _ in PARITY_CASES])
def test_paged_parity(arch, temp, chunk, seed):
    rng = np.random.default_rng(seed)
    eng = get_engine(arch, temp, chunk)
    scenario = gen_scenario(rng, n_req=6)
    assert_parity(eng, scenario)


def test_paged_parity_tight_pool():
    """Pool sized for barely over one sequence: constant eviction,
    exhaustion-requeue and CoW churn must not change a single token."""
    rng = np.random.default_rng(21)
    eng = get_engine("gqa", 1.0, 8)
    scenario = gen_scenario(rng, n_req=6)
    assert_parity(eng, scenario, n_blocks=MAX_LEN // BLOCK + 2,
                  check_serial=False)


def test_paged_parity_under_preemption():
    """Late high-priority arrivals preempt live low-priority slots;
    resumed requests replay into fresh blocks bit-identically."""
    rng = np.random.default_rng(31)
    eng = get_engine("gqa", 1.0, 8)
    scenario = gen_scenario(rng, n_req=4, max_new_hi=10)
    for r in scenario:
        r["priority"], r["at"] = 0, 0
    late = gen_scenario(rng, n_req=2)
    for r in late:
        r["priority"], r["at"] = 5, 4
    assert_parity(eng, scenario + late)
