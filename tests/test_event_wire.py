"""Wire back-compat for the RunEvent protocol.

Round-trips EVERY registered event type through ``to_wire``/``from_wire``
(including the traffic-PR ``ToolRetried``/``RunHedged`` and the
scheduler-v2 extended ``EngineStepped``), and pins the two compat
directions: OLDER wire payloads missing newer fields deserialize via
defaults, NEWER wire payloads carrying unknown fields are tolerated.
"""
import dataclasses

import pytest

from repro.core.events import (_EVENT_TYPES, MIN_WIRE_VERSION, WIRE_VERSION,
                               BudgetExceeded, EngineStepped, LLMCompleted,
                               OverheadIncurred, PlanCacheMiss, PlanCompiled,
                               PlanFallback, PlanProduced, ReflectionEmitted,
                               RunCompleted, RunDegraded, RunHedged,
                               RunStarted, SloAlertFired, StageCompleted,
                               StageStarted, ToolInvoked, ToolRetried,
                               WireVersionError, derive_trace,
                               events_from_wire, events_to_wire, from_wire,
                               to_wire)
from repro.core.metrics import FrameworkEvent, LLMEvent, ToolEvent

# one concrete instance of every wire-registered event type
SAMPLES = [
    RunStarted(t=0.0, pattern="agentx", task="do the thing"),
    StageStarted(t=1.0, index=0, name="search"),
    PlanProduced(t=1.5, index=0, plan={"steps": [{"tool": "google_search"}]}),
    LLMCompleted(t=2.0, event=LLMEvent("executor", 100, 20, 1.2, 2.0)),
    ToolInvoked(t=3.0, event=ToolEvent("serper", "google_search", 0.8,
                                       True, 3.0,
                                       args={"query": "q", "num_results": 8},
                                       result='{"organic": []}')),
    OverheadIncurred(t=3.5, event=FrameworkEvent("plan", 0.18, 3.5)),
    ReflectionEmitted(t=4.0, index=0, reflection={"success": True}),
    StageCompleted(t=4.5, index=0, success=True),
    ToolRetried(t=5.0, server="serper", tool="google_search", attempt=1,
                error="<tool-error ...: transient: injected>",
                backoff_s=0.5),
    RunHedged(t=5.5, server="fetch", tool="fetch", winner="hedge",
              primary_s=12.0, hedge_s=1.0, saved_s=3.0),
    RunCompleted(t=6.0, completed=True, data={"summaries": ["ok"]}),
    PlanCompiled(t=6.2, key="ab12" * 16, template="Search for {var} ...",
                 stages=3, nodes=5, dyn_nodes=1),
    PlanCacheMiss(t=6.3, key="ab12" * 16),
    PlanFallback(t=6.4, key="ab12" * 16, reason="node-failed:fetch",
                 stage=1),
    EngineStepped(t=7.0, live=3, queued=2, generated=3, prefilled=64,
                  preempted=1, blocks_in_use=12, prefix_hits=2),
    RunDegraded(t=0.0, tenant="acme", reason="soft budget exhaustion",
                from_pattern="agentx", to_pattern="agentx-compiled",
                from_deployment="faas", to_deployment="local"),
    BudgetExceeded(t=0.0, tenant="acme", kind="tokens", used=1_000_001.0,
                   budget=1_000_000.0),
    SloAlertFired(t=120.0, slo="success", window_start=60.0, window_s=60.0,
                  burn_rate=5.0, threshold=2.0, bad=2, total=4,
                  target=0.9),
]


def test_every_registered_type_has_a_sample():
    assert {type(s).__name__ for s in SAMPLES} == set(_EVENT_TYPES)


@pytest.mark.parametrize("event", SAMPLES,
                         ids=[type(s).__name__ for s in SAMPLES])
def test_roundtrip(event):
    wire = to_wire(event)
    assert wire["type"] == type(event).__name__
    back = from_wire(wire)
    assert back == event


def test_stream_roundtrip_and_trace():
    wire = events_to_wire(SAMPLES)
    back = events_from_wire(wire)
    assert back == SAMPLES
    trace = derive_trace(back)
    assert trace.llm_events and trace.tool_events and trace.framework_events


@pytest.mark.parametrize("event", SAMPLES,
                         ids=[type(s).__name__ for s in SAMPLES])
def test_unknown_wire_fields_tolerated(event):
    """A NEWER peer may attach fields we don't know — they must be
    dropped, not raised on (forward compat)."""
    wire = to_wire(event)
    wire["future_gauge"] = 123
    wire["another_new_field"] = {"nested": True}
    if isinstance(wire.get("event"), dict):
        wire["event"] = dict(wire["event"], future_nested_field=4.2)
    assert from_wire(wire) == event


def test_missing_newer_fields_default():
    """An OLDER peer's payload (pre-v2 EngineStepped without the
    admission gauges) still deserializes."""
    old = {"type": "EngineStepped", "t": 1.0, "live": 2, "queued": 0,
           "generated": 2}
    ev = from_wire(old)
    assert ev.prefilled == 0 and ev.preempted == 0


def test_pre_paging_enginestepped_payload_defaults():
    """A pre-paging EngineStepped payload (no paged-KV gauges) still
    deserializes — blocks_in_use/prefix_hits default to 0, which is
    exactly what the contiguous scheduler emits."""
    old = {"type": "EngineStepped", "t": 1.0, "live": 2, "queued": 0,
           "generated": 2, "prefilled": 16, "preempted": 0}
    ev = from_wire(old)
    assert ev.blocks_in_use == 0 and ev.prefix_hits == 0


def test_pre_plan_toolevent_payload_defaults():
    """A pre-plan-PR ToolInvoked payload (no args/result on the nested
    ToolEvent) still deserializes — the plan-compiler fields default."""
    old = {"type": "ToolInvoked", "t": 3.0,
           "event": {"server": "serper", "tool": "google_search",
                     "latency": 0.8, "ok": True, "t": 3.0}}
    ev = from_wire(old)
    assert ev.event.args is None and ev.event.result is None


def test_pre_tenancy_runstarted_payload_defaults():
    """A pre-tenancy RunStarted payload (no ``tenant`` field) still
    deserializes — the tenant defaults to the single default tenant."""
    old = {"type": "RunStarted", "t": 0.0, "pattern": "agentx",
           "task": "do the thing"}
    ev = from_wire(old)
    assert ev.tenant == ""


def test_tenant_stamped_runstarted_roundtrips():
    ev = RunStarted(t=0.0, pattern="react", task="t", tenant="acme")
    assert from_wire(to_wire(ev)) == ev
    assert to_wire(ev)["tenant"] == "acme"


def test_unknown_type_raises():
    with pytest.raises(KeyError):
        from_wire({"type": "NotARealEvent", "t": 0.0})


def test_new_events_have_json_safe_wire():
    import json
    for ev in (SAMPLES[8], SAMPLES[9]):   # ToolRetried, RunHedged
        assert json.loads(json.dumps(to_wire(ev))) == to_wire(ev)


def test_wire_fields_are_dataclass_fields():
    """to_wire emits exactly the dataclass fields + 'type' + the schema
    version stamp 'v' — the contract _known_fields filtering rests on."""
    for ev in SAMPLES:
        wire = to_wire(ev)
        names = {f.name for f in dataclasses.fields(ev)}
        assert set(wire) == names | {"type", "v"}


# -- explicit wire-schema versioning (durable-journal PR) -------------------


def test_wire_version_stamped():
    for ev in SAMPLES:
        assert to_wire(ev)["v"] == WIRE_VERSION


def test_old_stamped_payload_raises():
    """A payload stamped with a pre-MIN_WIRE_VERSION schema is rejected
    up front — never mis-parsed field by field."""
    wire = to_wire(SAMPLES[0])
    wire["v"] = MIN_WIRE_VERSION - 1
    with pytest.raises(WireVersionError):
        from_wire(wire)


def test_unstamped_payload_tolerated():
    """Pre-versioning payloads carry no 'v' at all — they predate the
    stamp, not the schema floor, and must keep deserializing."""
    wire = to_wire(SAMPLES[0])
    del wire["v"]
    assert from_wire(wire) == SAMPLES[0]


def test_newer_stamped_payload_tolerated():
    """A NEWER peer's stamp is fine: unknown fields drop, known fields
    parse (same forward-compat rule as unknown wire fields)."""
    wire = to_wire(SAMPLES[0])
    wire["v"] = WIRE_VERSION + 7
    wire["field_from_the_future"] = 1
    assert from_wire(wire) == SAMPLES[0]


def test_wire_version_error_is_value_error():
    """Callers already catching ValueError on corrupt payloads keep
    working."""
    assert issubclass(WireVersionError, ValueError)


# -- telemetry PR: the SLO alert event --------------------------------------


def test_slo_alert_roundtrips_and_is_json_safe():
    import json
    ev = SloAlertFired(t=120.0, slo="latency", window_start=60.0,
                       window_s=60.0, burn_rate=3.5, threshold=2.0,
                       bad=7, total=20, target=120.0)
    wire = to_wire(ev)
    assert json.loads(json.dumps(wire)) == wire
    assert from_wire(wire) == ev


def test_pre_telemetry_peer_alert_payload_forward_compat():
    """A NEWER monitor may stamp extra alert context (e.g. a runbook
    URL) — a pre-telemetry-schema peer must drop it, not raise, and the
    known burn-rate fields must survive the trip."""
    ev = SloAlertFired(t=60.0, slo="success", window_start=0.0,
                       window_s=60.0, burn_rate=10.0, threshold=2.0,
                       bad=6, total=6, target=0.9)
    wire = to_wire(ev)
    wire["runbook_url"] = "https://example.invalid/runbooks/slo-burn"
    wire["severity"] = "page"
    back = from_wire(wire)
    assert back == ev
    assert back.burn_rate == 10.0 and back.bad == 6


def test_run_monitor_snapshot_gauges_on_paged_backend():
    """RunMonitor (now a thin view over the telemetry registry) must
    keep its historical snapshot() keys populated when subscribed to the
    paged serving backend's EngineStepped stream."""
    from repro.serving import get_llm_backend, reset_llm_backends
    from repro.serving.engine import RunMonitor

    reset_llm_backends()
    try:
        backend = get_llm_backend("jax-batched-paged")
        monitor = RunMonitor()
        backend.subscribe(monitor)      # before the client exists
        out = backend.client().generate("count to three", 6)
        assert out.new_tokens > 0
        snap = monitor.snapshot()
        assert snap["engine_steps"] > 0
        # prefill yields the first token; decode steps produce the rest
        assert snap["engine_tokens"] >= out.new_tokens - 1
        assert snap["engine_prefill_tokens"] > 0
        assert snap["engine_peak_live"] >= 1
        assert snap["engine_blocks_in_use"] >= 0
        # the same numbers must be live on the registry the monitor wraps
        assert monitor.registry.total("repro_engine_steps_total") == \
            snap["engine_steps"]
    finally:
        reset_llm_backends()
