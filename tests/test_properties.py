"""Hypothesis property-based tests on system invariants."""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.metrics import llm_cost
from repro.core.schema import STAGE_SCHEMA, Schema, SchemaError, Field
from repro.data.tokenizer import CountTokenizer, HashTokenizer
from repro.env.clock import VirtualClock
from repro.faas.storage import KVStore, S3Store
from repro.mcp.protocol import McpRequest


@given(st.text(max_size=2000))
@settings(max_examples=60, deadline=None)
def test_tokenizer_roundtrip(text):
    tok = HashTokenizer(32000)
    ids = tok.encode(text, add_bos=False)
    assert tok.decode(ids) == text


@given(st.text(max_size=500))
@settings(max_examples=60, deadline=None)
def test_count_tokenizer_monotone_in_concat(text):
    a = CountTokenizer.count(text)
    b = CountTokenizer.count(text + " suffix")
    assert b >= a >= 0


@given(st.integers(0, 10**7), st.integers(0, 10**7))
@settings(max_examples=60, deadline=None)
def test_cost_eq1_linear(tin, tout):
    """Eq. 1: cost is exactly linear with the published per-token rates."""
    assert llm_cost(tin, tout) == pytest.approx(
        (tin * 0.15 + tout * 0.60) / 1e6)
    assert llm_cost(2 * tin, 2 * tout) == pytest.approx(2 * llm_cost(tin, tout))


@given(st.lists(st.floats(0, 10, allow_nan=False), min_size=1, max_size=30))
@settings(max_examples=40, deadline=None)
def test_virtual_clock_monotone(sleeps):
    clock = VirtualClock()
    t = clock.now()
    for dt in sleeps:
        clock.sleep(dt)
        assert clock.now() >= t
        t = clock.now()
    assert t == pytest.approx(sum(sleeps))


@given(st.dictionaries(st.text(min_size=1, max_size=20).filter(
    lambda s: "/" not in s), st.text(max_size=50), max_size=10))
@settings(max_examples=40, deadline=None)
def test_kvstore_write_read(items):
    store = KVStore()
    for k, v in items.items():
        store.write(k, v)
    for k, v in items.items():
        assert store.read(k) == v
    assert set(store.list()) == set(items)


@given(st.text(alphabet=st.characters(min_codepoint=48, max_codepoint=122),
               min_size=1, max_size=20),
       st.text(alphabet=st.characters(min_codepoint=48, max_codepoint=122),
               min_size=1, max_size=30))
@settings(max_examples=40, deadline=None)
def test_s3_uri_roundtrip(bucket, key):
    s3 = S3Store()
    uri = f"s3://{bucket}/{key}"
    b, k = S3Store.parse_uri(uri)
    assert b == bucket
    s3.put_object(uri, "data")
    assert s3.get_object(uri) == "data"


@given(st.lists(st.text(max_size=40), min_size=1, max_size=8))
@settings(max_examples=40, deadline=None)
def test_schema_validation(sub_tasks):
    obj = {"sub_tasks": sub_tasks}
    assert STAGE_SCHEMA.validate(obj) == obj
    with pytest.raises(SchemaError):
        STAGE_SCHEMA.validate({"sub_tasks": "not-a-list"})
    with pytest.raises(SchemaError):
        STAGE_SCHEMA.validate({})


@given(st.text(max_size=100), st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_mcp_request_json_roundtrip(query, rid):
    req = McpRequest("tools/call", {"name": "t", "arguments":
                                    {"query": query}}, id=rid,
                     session_id="s")
    back = McpRequest.from_json(req.to_json())
    assert back.params["arguments"]["query"] == query
    assert back.id == rid and back.session_id == "s"


# --- numerical invariants ---------------------------------------------------


@given(st.integers(1, 4), st.integers(2, 6), st.integers(1, 3))
@settings(max_examples=20, deadline=None)
def test_router_gates_sum_to_one(b, e, k):
    from repro.configs.base import MoEConfig
    from repro.models.moe import router
    k = min(k, e)
    moe = MoEConfig(n_experts=e, top_k=k, d_ff_expert=8)
    x = jax.random.normal(jax.random.key(b * 7 + e), (b * 3, 16))
    params = {"w_router": jax.random.normal(jax.random.key(0), (16, e))}
    gate, idx, aux = router(params, x, moe)
    assert np.allclose(np.asarray(jnp.sum(gate, -1)), 1.0, atol=1e-5)
    assert int(jnp.max(idx)) < e
    assert float(aux) >= 0.99  # E * sum(f_e * p_e) >= 1 by Cauchy-Schwarz


@given(st.integers(8, 64))
@settings(max_examples=10, deadline=None)
def test_sliding_window_masks_match(s):
    from repro.models.layers import causal_mask
    w = max(4, s // 3)
    m = np.asarray(causal_mask(s, s, window=w))
    for i in range(s):
        for j in range(s):
            assert m[i, j] == (j <= i and j > i - w)


@given(st.integers(1, 3), st.integers(16, 48))
@settings(max_examples=8, deadline=None)
def test_ssd_state_neutral_padding(b, s):
    """dt=0 padding must not change the final state (model invariant the
    chunked implementation relies on)."""
    from repro.kernels.ref import ssd_scan_ref
    h, p, n = 2, 8, 4
    ks = jax.random.split(jax.random.key(s), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    _, fin = ssd_scan_ref(x, dt, A, B, C)
    pad = 5
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    Bp = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
    Cp = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    _, fin2 = ssd_scan_ref(xp, dtp, A, Bp, Cp)
    assert float(jnp.max(jnp.abs(fin - fin2))) < 1e-5


# --- paged KV parity (the paging tentpole's property suite) -----------------
#
# Strategies draw a scenario SEED plus engine knobs; the scenario
# generator/runner is shared with tests/test_paging.py, so the seeded
# battery there and this wider search assert the exact same property:
# paged (prefix on AND off) == contiguous == serial, request for request.


@given(st.integers(0, 2 ** 31 - 1),
       st.sampled_from([("gqa", 0.0, 0), ("gqa", 1.0, 8), ("mla", 1.0, 0)]))
@settings(max_examples=6, deadline=None)
def test_paged_parity_property(seed, knobs):
    from paging_scenarios import assert_parity, gen_scenario, get_engine
    arch, temp, chunk = knobs
    rng = np.random.default_rng(seed)
    eng = get_engine(arch, temp, chunk)
    assert_parity(eng, gen_scenario(rng, n_req=int(rng.integers(2, 7))))


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=4, deadline=None)
def test_paged_parity_tight_pool_property(seed):
    """Random scenarios under a pool barely over one sequence wide:
    eviction/exhaustion churn never changes a token."""
    from paging_scenarios import (BLOCK, MAX_LEN, assert_parity,
                                  gen_scenario, get_engine)
    rng = np.random.default_rng(seed)
    eng = get_engine("gqa", 1.0, 8)
    assert_parity(eng, gen_scenario(rng, n_req=5),
                  n_blocks=MAX_LEN // BLOCK + 2, check_serial=False)


@given(st.lists(st.integers(1, 300), min_size=1, max_size=24),
       st.integers(2, 16))
@settings(max_examples=40, deadline=None)
def test_prefix_chain_commits_to_whole_prefix(ids, block_size):
    """A chained block key is a commitment to the entire token prefix:
    perturbing ANY earlier token changes every key at or after it."""
    from repro.serving import prefix_block_keys
    keys = prefix_block_keys(ids, block_size, "salt")
    assert len(keys) == len(ids) // block_size
    if not keys:
        return
    mutated = list(ids)
    mutated[0] += 1
    assert all(a != b for a, b in
               zip(keys, prefix_block_keys(mutated, block_size, "salt")))
