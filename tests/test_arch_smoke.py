"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each family runs one forward/train step on CPU, asserting output shapes
and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models import init_params, init_cache, prefill, decode_step
from repro.models.model import forward, loss_fn
from repro.training import OptConfig, init_opt_state, make_train_step

ALL_ARCHS = sorted(ARCHS)


def _batch(cfg, key, b=2, s=32):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.frontend:
        batch["frontend_embeds"] = 0.02 * jax.random.normal(
            key, (b, cfg.frontend_positions, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_constraints(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 4
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch, rng_key):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, rng_key)
    batch = _batch(cfg, rng_key)
    logits, aux = jax.jit(lambda p, b: forward(
        p, cfg, b["tokens"], b.get("frontend_embeds")))(params, batch)
    P = cfg.frontend_positions if cfg.frontend else 0
    assert logits.shape == (2, 32 + P, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step(arch, rng_key):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, rng_key)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, OptConfig(lr=1e-3, warmup_steps=1,
                                                  total_steps=10)))
    batch = _batch(cfg, rng_key)
    params2, opt2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, params2)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_forward(arch, rng_key):
    cfg = get_config(arch).reduced()
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init_params(cfg, rng_key)
    S = 17
    toks = jax.random.randint(rng_key, (2, S), 0, cfg.vocab_size)
    fe = None
    if cfg.frontend:
        fe = 0.02 * jax.random.normal(
            rng_key, (2, cfg.frontend_positions, cfg.d_model))
    logits_full, _ = forward(params, cfg, toks, fe, remat=False)
    P = cfg.frontend_positions if cfg.frontend else 0
    if cfg.arch_type == "ssm":
        cache = init_cache(cfg, 2, S)
        lg = None
        for i in range(S):
            lg, cache = decode_step(params, cfg, cache, toks[:, i:i + 1],
                                    jnp.int32(i))
        assert float(jnp.max(jnp.abs(lg - logits_full[:, -1]))) < 3e-3
        return
    Sp = S - 1
    last, cache = prefill(params, cfg, toks[:, :Sp], fe)
    assert float(jnp.max(jnp.abs(last - logits_full[:, P + Sp - 1]))) < 3e-3

    def pad(path, x):
        k = None
        for p in reversed(path):
            if hasattr(p, "key"):
                k = p.key
                break
        if k in ("k", "v"):
            w = [(0, 0)] * x.ndim
            w[x.ndim - 3] = (0, 1)
            return jnp.pad(x, w)
        if k in ("ckv", "kpe"):
            w = [(0, 0)] * x.ndim
            w[x.ndim - 2] = (0, 1)
            return jnp.pad(x, w)
        return x
    cache = jax.tree_util.tree_map_with_path(pad, cache)
    lg, _ = decode_step(params, cfg, cache, toks[:, Sp:], jnp.int32(P + Sp))
    assert float(jnp.max(jnp.abs(lg - logits_full[:, -1]))) < 3e-3
