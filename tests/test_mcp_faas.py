"""MCP protocol / servers / FaaS platform behaviour tests."""
import json

import pytest

from repro.env.world import World
from repro.faas.deployments import (FAAS_TOOL_SUBSET, SERVER_FACTORIES,
                                    deploy_distributed, deploy_local,
                                    deploy_monolithic)
from repro.faas.platform import FaaSPlatform, LAMBDA_GBS_USD
from repro.faas.storage import S3Store
from repro.mcp.protocol import McpRequest, McpResponse
from repro.mcp.server import ToolContext

TABLE1 = {"code-execution": 4, "rag": 1, "yfinance": 17, "serper": 13,
          "arxiv": 8, "fetch": 9, "filesystem": 10, "s3": 3}


@pytest.mark.parametrize("server,count", sorted(TABLE1.items()))
def test_table1_tool_counts(server, count):
    assert len(SERVER_FACTORIES[server]().tools) == count


def test_jsonrpc_roundtrip():
    req = McpRequest("tools/call", {"name": "fetch", "arguments": {"url": "u"}},
                     session_id="sid-1")
    back = McpRequest.from_json(req.to_json())
    assert back.method == req.method
    assert back.params == {"name": "fetch", "arguments": {"url": "u"}}
    assert back.session_id == "sid-1"
    resp = McpResponse(1, {"ok": True}, session_id="sid-1")
    back = McpResponse.from_json(resp.to_json())
    assert back.ok and back.session_id == "sid-1"


def test_unknown_tool_is_rpc_error_not_crash():
    world = World(0)
    clients, _ = deploy_local(world, ["serper"])
    out = clients["serper"].call_tool("nonexistent", {})
    assert out.startswith("<tool-error")


def test_local_hints_applied_only_locally():
    world = World(0)
    clients, _ = deploy_local(world, ["fetch"])
    [fetch] = [t for t in clients["fetch"].list_tools() if t.name == "fetch"]
    assert "after using the Google Search tool" in fetch.spec.description

    platform = FaaSPlatform(World(0))
    fclients = deploy_distributed(World(0), platform, ["fetch"])
    [fetch] = [t for t in fclients["fetch"].list_tools()
               if t.name == "fetch"]
    assert "after using the Google Search tool" not in fetch.spec.description


def test_faas_hosts_tool_subset():
    platform = FaaSPlatform(World(0))
    clients = deploy_distributed(World(0), platform, ["yfinance"])
    names = {t.name for t in clients["yfinance"].list_tools()}
    assert names == set(FAAS_TOOL_SUBSET["yfinance"])


def test_cold_start_then_warm():
    world = World(0)
    platform = FaaSPlatform(world)
    clients = deploy_distributed(world, platform, ["serper"])
    platform.reset_accounting()
    clients["serper"].call_tool("google_search", {"query": "quantum"})
    clients["serper"].call_tool("google_search", {"query": "quantum"})
    colds = [i.cold_start for i in platform.invocations]
    assert colds == [False, False]  # initialize() already booted the container


def test_billing_eq2():
    world = World(0)
    platform = FaaSPlatform(world)
    clients = deploy_distributed(world, platform, ["s3"])
    platform.reset_accounting()
    clients["s3"].call_tool("s3_write", {"uri": "s3://b/k", "content": "x"})
    [inv] = platform.invocations
    expected = inv.billed_gb_s * LAMBDA_GBS_USD + 0.2 / 1e6
    assert abs(inv.cost_usd - expected) < 1e-12
    assert inv.billed_gb_s == pytest.approx(
        max(inv.duration_s, 0.001) * platform.functions["mcp-s3"].memory_mb / 1024)


def test_session_statefulness_and_isolation():
    world = World(0)
    platform = FaaSPlatform(world)
    c1 = deploy_distributed(world, platform, ["rag"])["rag"]
    assert platform.sessions.count() == 1
    c2_clients = deploy_distributed(world, platform, ["rag"])
    # second deploy replaces function; sessions table still tracks ids
    c1.close()
    assert platform.sessions.get(c1.session_id) is None


def test_ephemeral_tmp_vs_s3():
    world = World(0)
    platform = FaaSPlatform(world)
    clients = deploy_distributed(world, platform, ["code-execution"])
    out = clients["code-execution"].call_tool("execute_python", {
        "code": "import matplotlib.pyplot as plt\n"
                "plt.plot([1,2],[3,4])\n"
                "plt.savefig('s3://dummy-bucket/agent/x.png')"})
    assert json.loads(out)["status"] == "ok"
    assert platform.s3.exists("s3://dummy-bucket/agent/x.png")


def test_monolithic_routes_and_bills_summed_memory():
    world = World(0)
    platform = FaaSPlatform(world)
    clients = deploy_monolithic(world, platform, ["serper", "fetch", "s3"])
    mem = platform.functions["mcp-monolith"].memory_mb
    assert mem == sum(max(SERVER_FACTORIES[n]().memory_mb, 128)
                      for n in ("serper", "fetch", "s3"))
    out = clients["serper"].call_tool("google_search", {"query": "edge"})
    assert "organic" in out


def test_s3_uri_parsing():
    s3 = S3Store()
    with pytest.raises(ValueError):
        s3.put_object("not-a-uri", "x")
    s3.put_object("s3://b/path/k.txt", "hello")
    assert s3.get_object("s3://b/path/k.txt") == "hello"
    assert s3.list_objects("s3://b/path") == ["s3://b/path/k.txt"]


def test_sandbox_blocks_arbitrary_imports():
    world = World(0)
    clients, _ = deploy_local(world, ["code-execution"])
    out = clients["code-execution"].call_tool(
        "execute_python", {"code": "import os\nprint(os.getcwd())"})
    assert json.loads(out)["status"] == "error"
    assert "not preinstalled" in json.loads(out)["error"]
