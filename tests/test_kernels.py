"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import (decode_attention_op, flash_attention_op,
                           paged_decode_attention_op, rmsnorm_op,
                           ssd_scan_op)
from repro.kernels.ref import (decode_attention_ref, flash_attention_ref,
                               paged_decode_attention_ref, rmsnorm_ref,
                               ssd_scan_ref)

TOL = {jnp.float32: 2e-4, jnp.bfloat16: 4e-2}


@pytest.mark.parametrize("b,s,hq,hkv,hd", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 8, 2, 64),     # GQA
    (1, 192, 4, 1, 128),    # MQA, non-pow2 seq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention(b, s, hq, hkv, hd, dtype, causal, window):
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(k1, (b, s, hq, hd), dtype)
    k = jax.random.normal(k2, (b, s, hkv, hd), dtype)
    v = jax.random.normal(k3, (b, s, hkv, hd), dtype)
    out = flash_attention_op(q, k, v, causal=causal, window=window,
                             block_q=64, block_k=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    err = jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))
    assert float(err) < TOL[dtype], float(err)


@pytest.mark.parametrize("b,c,hq,hkv,hd", [
    (2, 128, 8, 2, 64),
    (3, 300, 4, 1, 64),
    (1, 64, 16, 16, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(b, c, hq, hkv, hd, dtype):
    k1, k2, k3 = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(k1, (b, hq, hd), dtype)
    k = jax.random.normal(k2, (b, c, hkv, hd), dtype)
    v = jax.random.normal(k3, (b, c, hkv, hd), dtype)
    lens = jnp.arange(1, b + 1, dtype=jnp.int32) * (c // (b + 1)) + 1
    out = decode_attention_op(q, k, v, lens, block_k=64, interpret=True)
    ref = decode_attention_ref(q, k, v, lens)
    err = jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))
    assert float(err) < TOL[dtype], float(err)


def _paged_inputs(key, b, hq, hkv, hd, n_blocks, bs, mb, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    q = jax.random.normal(k1, (b, hq, hd), dtype)
    kp = jax.random.normal(k2, (n_blocks, bs, hkv, hd), dtype)
    vp = jax.random.normal(k3, (n_blocks, bs, hkv, hd), dtype)
    # tables draw WITH junk: rows past the valid length point at random
    # physical blocks, exactly like a scheduler table mid-flight
    tables = jax.random.randint(k4, (b, mb), 0, n_blocks, jnp.int32)
    return q, kp, vp, tables


@pytest.mark.parametrize("b,hq,hkv,hd,bs,mb", [
    (2, 8, 2, 64, 16, 4),       # GQA
    (3, 4, 1, 64, 8, 6),        # MQA, small blocks
    (1, 16, 16, 128, 32, 2),    # MHA, wide blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention(b, hq, hkv, hd, bs, mb, dtype):
    n_blocks = 2 * b * mb
    q, kp, vp, tables = _paged_inputs(jax.random.key(7), b, hq, hkv, hd,
                                      n_blocks, bs, mb, dtype)
    lens = jnp.asarray([(i * mb * bs) // b + 1 for i in range(b)], jnp.int32)
    out = paged_decode_attention_op(q, kp, vp, tables, lens, interpret=True)
    ref = paged_decode_attention_ref(q, kp, vp, tables, lens)
    err = jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))
    assert float(err) < TOL[dtype], float(err)


@pytest.mark.parametrize("length", [
    0,          # empty sequence: exact-zero output, no NaN
    8,          # exactly one full block
    13,         # last block partially filled
    32,         # every table slot full (max-blocks)
])
def test_paged_decode_attention_edges(length):
    b, hq, hkv, hd, bs, mb, n_blocks = 2, 8, 2, 64, 8, 4, 16
    q, kp, vp, tables = _paged_inputs(jax.random.key(11), b, hq, hkv, hd,
                                      n_blocks, bs, mb, jnp.float32)
    lens = jnp.asarray([length, 32 - length], jnp.int32)
    out = paged_decode_attention_op(q, kp, vp, tables, lens, interpret=True)
    ref = paged_decode_attention_ref(q, kp, vp, tables, lens)
    assert not bool(jnp.any(jnp.isnan(out)))
    err = jnp.max(jnp.abs(out - ref))
    assert float(err) < TOL[jnp.float32], float(err)
    if length == 0:
        assert float(jnp.max(jnp.abs(out[0]))) == 0.0


def test_paged_decode_matches_dense_gather():
    """Gathering the pool through the table and running the dense decode
    kernel must agree with the paged kernel reading through the table."""
    b, hq, hkv, hd, bs, mb, n_blocks = 2, 8, 2, 64, 8, 4, 16
    q, kp, vp, tables = _paged_inputs(jax.random.key(13), b, hq, hkv, hd,
                                      n_blocks, bs, mb, jnp.float32)
    lens = jnp.asarray([9, 25], jnp.int32)
    paged = paged_decode_attention_op(q, kp, vp, tables, lens,
                                      interpret=True)
    kd = kp[tables].reshape(b, mb * bs, hkv, hd)
    vd = vp[tables].reshape(b, mb * bs, hkv, hd)
    dense = decode_attention_op(q, kd, vd, lens, block_k=bs, interpret=True)
    err = jnp.max(jnp.abs(paged - dense))
    assert float(err) < TOL[jnp.float32], float(err)


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 64, 2, 32, 16, 32),
    (2, 100, 3, 32, 16, 32),      # padded tail
    (1, 256, 1, 64, 64, 64),
])
def test_ssd_scan(b, s, h, p, n, chunk):
    ks = jax.random.split(jax.random.key(2), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    y, fin = ssd_scan_op(x, dt, A, B, C, chunk=chunk, interpret=True)
    yr, finr = ssd_scan_ref(x, dt, A, B, C)
    assert float(jnp.max(jnp.abs(y - yr))) < 2e-3
    assert float(jnp.max(jnp.abs(fin - finr))) < 2e-3


def test_ssd_scan_matches_model_chunked():
    """Pallas kernel == the model's jnp chunked path == naive recurrence."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.key(3), 5)
    b, s, h, p, n = 2, 96, 2, 32, 16
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    y1, f1 = ssd_chunked(x, dt, A, B, C, chunk=32)
    y2, f2 = ssd_scan_op(x, dt, A, B, C, chunk=32, interpret=True)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 2e-3
    assert float(jnp.max(jnp.abs(f1 - f2))) < 2e-3


@pytest.mark.parametrize("shape", [(8, 128), (5, 7, 96), (300, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    x = jax.random.normal(jax.random.key(4), shape, dtype)
    scale = jax.random.normal(jax.random.key(5), shape[-1:], jnp.float32)
    out = rmsnorm_op(x, scale, block_rows=64, interpret=True)
    ref = rmsnorm_ref(x, scale)
    err = jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))
    assert float(err) < TOL[dtype]
