"""End-to-end serving driver (deliverable b): serve a small model with
batched requests through the continuous-batching scheduler, with the
real-JAX-engine-backed agent LLM in the loop.

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys
import time

sys.path.insert(0, "src")

from repro.apps.session import RunSpec, Session  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.core.llm import JaxLLMBackend  # noqa: E402
from repro.serving import BatchScheduler, Engine, RunMonitor  # noqa: E402


def main():
    cfg = get_config("qwen1.5-4b").reduced()
    engine = Engine(cfg, temperature=0.7)
    sched = BatchScheduler(engine, n_slots=4)

    print(f"# batched serving on {cfg.name} "
          f"({cfg.n_params() / 1e6:.1f}M params)")
    prompts = [
        "Summarize the AgentX workflow pattern.",
        "What is the Model Context Protocol?",
        "Compare monolithic vs distributed FaaS MCP deployment.",
        "Why does ReAct consume more input tokens than AgentX?",
        "Explain cold starts in AWS Lambda.",
        "What does the Planner agent filter?",
    ]
    t0 = time.time()
    for p in prompts:
        sched.submit(p, max_new=12)
    results = sched.run()
    wall = time.time() - t0
    print(f"# served {len(results)} requests in {wall:.1f}s "
          f"({len(results) * 12 / wall:.1f} tok/s, CPU)")

    # real JAX engine as the agents' LLM endpoint (decisions from the
    # oracle policy, every completion runs actual prefill+decode); the
    # serving-side RunMonitor observes the run-event stream live
    print("# AgentX with the JAX engine in the loop:")
    monitor = RunMonitor()
    session = Session(on_event=monitor)
    t0 = time.time()
    r = session.execute(RunSpec(
        "web_search", "edge", "agentx", "local", seed=0,
        backend_factory=lambda world, policy, trace: JaxLLMBackend(
            world, policy, engine, trace, max_gen=4)))
    snap = monitor.snapshot()
    print(f"#   success={r.success} agent_invocations="
          f"{r.trace.agent_invocations} wall={time.time() - t0:.1f}s "
          f"(every inference ran real prefill+decode)")
    print(f"#   live monitor: llm_calls={snap['llm_calls']} "
          f"tokens={snap['input_tokens']}/{snap['output_tokens']} "
          f"tool_calls={snap['tool_calls']} in_flight={snap['in_flight']}")


if __name__ == "__main__":
    main()
