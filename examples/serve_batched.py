"""End-to-end serving driver: true continuous batching — one jitted
decode step advances every live slot — with the real-JAX-engine-backed
agent LLM in the loop via the ``@register_llm_backend`` registry.

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys
import time
from concurrent.futures import ThreadPoolExecutor

sys.path.insert(0, "src")

from repro.apps.session import RunSpec, Session  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.serving import (BatchScheduler, Engine, EngineClient,  # noqa: E402
                           RunMonitor, get_llm_backend, llm_backend_names,
                           reset_llm_backends)


def main():
    cfg = get_config("qwen1.5-4b").reduced()
    engine = Engine(cfg, temperature=0.7)
    monitor = RunMonitor()
    sched = BatchScheduler(engine, n_slots=4, max_len=128, on_event=monitor)

    print(f"# batched serving on {cfg.name} "
          f"({cfg.n_params() / 1e6:.1f}M params)")
    prompts = [
        "Summarize the AgentX workflow pattern.",
        "What is the Model Context Protocol?",
        "Compare monolithic vs distributed FaaS MCP deployment.",
        "Why does ReAct consume more input tokens than AgentX?",
        "Explain cold starts in AWS Lambda.",
        "What does the Planner agent filter?",
    ]
    t0 = time.time()
    for p in prompts:
        sched.submit(p, max_new=12)
    results = sched.drain()
    wall = time.time() - t0
    toks = sum(r.new_tokens for r in results.values())
    print(f"# served {len(results)} requests ({toks} new tokens) in "
          f"{wall:.1f}s — {monitor.engine_steps} decode steps, "
          f"peak occupancy {monitor.engine_peak_live}/{sched.n_slots}")

    # concurrent callers multiplexed onto the SAME batch via EngineClient
    # (fresh monitor: the drain above already peaked the first one)
    client_monitor = RunMonitor()
    sched.subscribe(client_monitor)
    client = EngineClient(sched)
    t0 = time.time()
    with ThreadPoolExecutor(max_workers=6) as pool:
        outs = list(pool.map(lambda p: client.generate(p, 8), prompts))
    print(f"# EngineClient: 6 threads, {sum(o.new_tokens for o in outs)} "
          f"tokens in {time.time() - t0:.1f}s, peak occupancy "
          f"{client_monitor.engine_peak_live}/{sched.n_slots}")

    # the registry route: RunSpec.llm="jax-batched" puts the real engine
    # in the agent loop; execute_many fan-out shares the decode batch
    print(f"# llm backends: {llm_backend_names()}")
    reset_llm_backends()
    run_monitor = RunMonitor()
    get_llm_backend("jax-batched").subscribe(run_monitor)
    session = Session(on_event=run_monitor)
    t0 = time.time()
    rs = session.execute_many(
        [RunSpec("web_search", "edge", "agentx", seed=s, llm="jax-batched")
         for s in range(3)], max_workers=3)
    snap = run_monitor.snapshot()
    print(f"#   {len(rs)} agent runs success="
          f"{[r.success for r in rs]} wall={time.time() - t0:.1f}s "
          f"(every completion through the slot-batched engine)")
    print(f"#   live monitor: llm_calls={snap['llm_calls']} "
          f"tokens={snap['input_tokens']}/{snap['output_tokens']} "
          f"engine_steps={snap['engine_steps']} "
          f"peak_occupancy={snap['engine_peak_live']} "
          f"engine_tokens={snap['engine_tokens']}")


if __name__ == "__main__":
    main()
