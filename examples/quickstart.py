"""Quickstart: the three layers of the framework in one script.

1. Train a reduced model from the zoo for a few steps (JAX substrate).
2. Serve it (prefill + decode with a KV cache).
3. Run an AgentX workflow against FaaS-hosted MCP servers, powered by that
   same serving engine (the full paper stack end-to-end).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp  # noqa: E402

from repro.apps.session import RunSpec, Session, score_run  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.serving import Engine  # noqa: E402
from repro.training import train  # noqa: E402


def main():
    # 1 -- train -------------------------------------------------------
    cfg = get_config("tinyllama-1.1b").reduced()
    print(f"[1/3] training {cfg.name} ({cfg.n_params() / 1e6:.2f}M params)")
    out = train(cfg, steps=15, batch=2, seq_len=64, log_every=5)
    print("      losses:", [round(h["loss"], 3) for h in out["history"]])

    # 2 -- serve -------------------------------------------------------
    print("[2/3] serving: prefill + decode")
    engine = Engine(cfg, params=out["params"], temperature=0.8)
    gen = engine.generate("agentic workflows on serverless clouds",
                          max_new_tokens=12)
    print(f"      prompt={gen.prompt_tokens} tok -> generated "
          f"{gen.new_tokens} tok")

    # 3 -- AgentX over FaaS MCP ----------------------------------------
    print("[3/3] AgentX workflow, FaaS-hosted MCP (distributed, Fig. 2c)")
    session = Session()
    result = session.execute(
        RunSpec("web_search", "quantum", "agentx", "faas", seed=0))
    score = score_run(result)
    t = result.trace
    print(f"      success={result.success} latency={result.total_latency:.1f}s"
          f" tokens={t.input_tokens}/{t.output_tokens}"
          f" llm=${t.llm_cost:.4f} lambda=${result.faas_cost:.6f}"
          f" accuracy={score.total:.1f}/100")
    print(f"      artifact: {result.artifact_path}")


if __name__ == "__main__":
    main()
