"""End-to-end training driver: ~100M-parameter model for a few hundred
steps on the agent-trace corpus (text produced by the agentic benchmarks —
the two halves of the framework feeding each other).

    PYTHONPATH=src python examples/train_100m.py [--steps 200]

Note: CPU container — a 100M model at batch 8 x seq 256 runs ~1-2 s/step;
use --steps to trade time for loss curve length.
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.apps.session import RunSpec, Session  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.training import train  # noqa: E402
from repro.training.data import AgentTraceCorpus  # noqa: E402


def harvest_corpus() -> list:
    texts = []
    runs = Session().execute_many(
        [RunSpec(app, inst, "agentx", seed=0)
         for app, inst in [("web_search", "quantum"),
                           ("research_report", "why")]], max_workers=2)
    for r in runs:
        if r.artifact:
            texts.append(r.artifact)
        texts.extend(r.extras["outcome"].get("summaries", []))
    return texts or ["agentic workflows on serverless clouds"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # ~100M-parameter member of the tinyllama family
    base = get_config("tinyllama-1.1b")
    cfg = dataclasses.replace(base, name="tinyllama-100m", n_layers=6,
                              d_model=768, n_heads=12, n_kv_heads=4,
                              d_ff=2048, vocab_size=32000)
    print(f"# {cfg.name}: {cfg.n_params() / 1e6:.1f}M params, "
          f"{args.steps} steps x batch {args.batch} x seq {args.seq}")

    corpus = AgentTraceCorpus(harvest_corpus(), cfg.vocab_size, args.seq,
                              args.batch)
    out = train(cfg, steps=args.steps, batch=args.batch, seq_len=args.seq,
                data=corpus, log_every=max(args.steps // 10, 1),
                checkpoint_dir="artifacts/ckpt_100m")
    for h in out["history"]:
        print(f"step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"lr {h['lr']:.2e}  gnorm {h['grad_norm']:.2f}")
    print(f"# wall {out['wall_s']:.0f}s; checkpoint in artifacts/ckpt_100m")


if __name__ == "__main__":
    main()
