"""Beyond-paper AgentX extensions (the paper's own §7 future-work list):

  1. CoT pre-reasoning before the Stage Generator and Planner — fewer
     §6.1 anomalies (duplicate write stages, missing tool params) at the
     cost of extra reasoning tokens.
  2. Parallel execution of independent stages — wall time = max(branch)
     instead of sum, shown on the multi-topic digest app.

    PYTHONPATH=src python examples/agentx_extensions.py
"""
import statistics
import sys

sys.path.insert(0, "src")

from repro.apps.session import RunSpec, Session  # noqa: E402

N = 6


def main():
    session = Session()
    print("=== parallel stages (multi_topic_digest, 3 independent topics) ===")
    for pat in ("agentx", "agentx-parallel"):
        rs = session.execute_many(
            [RunSpec("multi_topic_digest", "tech", pat, seed=s)
             for s in range(N)], max_workers=4)
        lat = statistics.mean(r.total_latency for r in rs)
        print(f"  {pat:17s} latency={lat:6.1f}s "
              f"success={sum(r.success for r in rs)}/{N}")

    print("\n=== CoT pre-reasoning (research_report, anomaly-prone) ===")
    for pat in ("agentx", "agentx-cot"):
        rs = session.execute_many(
            [RunSpec("research_report", "why", pat, seed=s)
             for s in range(12)], max_workers=4)
        sr = sum(r.success for r in rs) / 12
        tin = statistics.mean(r.trace.input_tokens for r in rs)
        cost = statistics.mean(r.trace.llm_cost for r in rs)
        print(f"  {pat:17s} success={sr:4.0%} in_tok={tin:6.0f} "
              f"llm=${cost:.4f}")
    print("\nCoT trades ~10% more tokens for recovering the §6.1 failure "
          "modes; parallel stages cut digest latency ~40%.")


if __name__ == "__main__":
    main()
