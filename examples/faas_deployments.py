"""MCP deployment architectures head-to-head (paper Fig. 2 + §4; the
monolithic-vs-distributed comparison the paper leaves to future work):

  local (Fig. 2a) vs distributed FaaS (Fig. 2c) vs monolithic FaaS
  (Fig. 2b) vs A2A remote delegation (§2.3)

reporting per-call latency, cold starts, and Lambda cost per Eq. 2.
The deployment list comes straight from the ``@register_deployment``
registry — registering a new backend adds a row with no edit here.

    PYTHONPATH=src python examples/faas_deployments.py
"""
import statistics
import sys

sys.path.insert(0, "src")

from repro.apps.cache import RunCache  # noqa: E402
from repro.apps.session import RunSpec, Session  # noqa: E402
from repro.faas.deployments import deployment_names  # noqa: E402

N = 4
APPS = [("web_search", "materials"), ("stock_correlation", "cola"),
        ("research_report", "flow")]


def main():
    session = Session(cache=RunCache())
    print(f"{'app':18s} {'deployment':10s} {'lat_s':>7s} {'tool_s':>7s} "
          f"{'lambda_$':>10s} {'ok':>5s}")
    for app, inst in APPS:
        for dep in deployment_names():
            runs = session.execute_many(
                [RunSpec(app, inst, "react", dep, seed=s)
                 for s in range(N)], max_workers=N)
            lat = statistics.mean(r.total_latency for r in runs)
            tool = statistics.mean(r.trace.tool_latency for r in runs)
            cost = statistics.mean(r.faas_cost for r in runs)
            ok = sum(r.success for r in runs)
            print(f"{app:18s} {dep:10s} {lat:7.1f} {tool:7.1f} "
                  f"{cost:10.6f} {ok}/{N}")
    print("\nmonolithic bills the summed memory footprint per call but "
          "shares one warm container across servers (paper §4's predicted "
          "trade-off); a2a pays a task round trip per tool call but needs "
          "no Lambda platform at all.")
    print(f"run cache: {session.cache.stats()}")


if __name__ == "__main__":
    main()
