"""Compare AgentX against ReAct and Magentic-One on one application
(paper §5): success, latency breakdown, tokens, cost, accuracy — local MCP
vs FaaS-hosted MCP.

    PYTHONPATH=src python examples/agentx_vs_baselines.py [app] [instance]
"""
import statistics
import sys

sys.path.insert(0, "src")

from repro.apps.session import RunSpec, Session, score_run  # noqa: E402
from repro.core.runtime import pattern_names  # noqa: E402

N = 3


def main():
    app = sys.argv[1] if len(sys.argv) > 1 else "web_search"
    inst = sys.argv[2] if len(sys.argv) > 2 else "quantum"
    session = Session()
    print(f"=== {app} / {inst} ({N} runs each) ===")
    hdr = (f"{'pattern':9s} {'dep':5s} {'ok':>4s} {'lat_s':>7s} "
           f"{'llm_s':>6s} {'tool_s':>6s} {'fw_s':>5s} {'in_tok':>7s} "
           f"{'out':>5s} {'$llm':>7s} {'score':>5s}")
    print(hdr)
    for dep in ("local", "faas"):
        for pattern in pattern_names(tag="paper"):
            runs = session.execute_many(
                [RunSpec(app, inst, pattern, dep, seed=s)
                 for s in range(N)], max_workers=N)
            scores = [score_run(r).total for r in runs]
            m = lambda f: statistics.mean(f(r) for r in runs)
            print(f"{pattern:9s} {dep:5s} "
                  f"{sum(r.success for r in runs)}/{N:<2d} "
                  f"{m(lambda r: r.total_latency):7.1f} "
                  f"{m(lambda r: r.trace.llm_latency):6.1f} "
                  f"{m(lambda r: r.trace.tool_latency):6.1f} "
                  f"{m(lambda r: r.trace.framework_latency):5.1f} "
                  f"{m(lambda r: r.trace.input_tokens):7.0f} "
                  f"{m(lambda r: r.trace.output_tokens):5.0f} "
                  f"{m(lambda r: r.trace.llm_cost):7.4f} "
                  f"{statistics.mean(scores):5.1f}")


if __name__ == "__main__":
    main()
