"""A2A composition (paper §2.3/§7 future work): MCP gives one agent its
tools; A2A gives agents each other. A coordinator discovers two remote
agents by AgentCard and delegates whole sub-workflows to them — and a
``RunMonitor`` subscribed on the A2A client observes the *remote* runs'
event streams, wire-streamed back on the task envelopes, exactly as if
the runs were in-process.

    PYTHONPATH=src python examples/a2a_composition.py
"""
import sys

sys.path.insert(0, "src")

from repro.env.world import World  # noqa: E402
from repro.mcp.a2a import A2AClient, expose_app_as_agent  # noqa: E402
from repro.serving.engine import RunMonitor  # noqa: E402


def main():
    world = World(seed=3)
    monitor = RunMonitor()
    client = A2AClient(world, on_event=monitor)

    researcher = expose_app_as_agent(
        world, "research_report", "agentx", "faas",
        url="https://agents.example/researcher")
    analyst = expose_app_as_agent(
        world, "stock_correlation", "react", "faas",
        url="https://agents.example/analyst")

    for server in (researcher, analyst):
        card = client.discover(server)
        print(f"discovered: {card.name} — skills: "
              f"{[s.id for s in card.skills]}")

    t1 = client.delegate(researcher.card.name, "research_report",
                         "summarize the paper 'Why Do Multi-Agent LLM "
                         "Systems Fail?'")
    t2 = client.delegate(analyst.card.name, "stock_correlation",
                         "plot apple / alphabet / microsoft")
    print(f"\nresearcher task: {t1.status}, artifact "
          f"{len(t1.artifacts[0]['text']) if t1.artifacts else 0} chars")
    print(f"analyst task:    {t2.status}, artifact "
          f"{len(t2.artifacts[0]['text']) if t2.artifacts else 0} chars")
    print(f"coordinator wall time (virtual): {world.clock.now():.1f}s")

    snap = monitor.snapshot()
    print(f"\nremote runs observed live over the wire: "
          f"{snap['runs_completed']} runs, {snap['llm_calls']} LLM calls, "
          f"{snap['tool_calls']} tool calls, "
          f"{snap['input_tokens']} input tokens")


if __name__ == "__main__":
    main()
